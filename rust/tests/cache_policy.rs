//! Differential suite for the pluggable image-cache policies:
//!
//! * the default `PressureSweep` policy must evict **exactly** like the
//!   pre-policy engine — the reference below is a verbatim copy of the
//!   old `gc_images_node` loop, and randomized scenarios must agree on
//!   freed bytes, surviving images, surviving layers, and disk usage;
//! * every policy must be byte-identical across shard counts and across
//!   repeats under churn + the peer swarm;
//! * the terminal-outcome accounting identity (`completed + failed_pulls
//!   + unschedulable + lost_to_crash == submitted`) must hold under
//!   every policy;
//! * the recency (LRU) and popularity policies must strictly beat the
//!   fixed pressure sweep on cache hit rate for a Zipf-skewed workload;
//! * the prefetch-on-intent policy must actually warm layers without
//!   breaking the cluster invariants.

use lrsched::cluster::{evict_layers_on, ClusterState, Node, NodeId, PodBuilder, Resources};
use lrsched::registry::{hub, ImageRef, LayerInterner, LayerSet, Registry};
use lrsched::sim::kubelet::{gc_images, ImageLayerStore};
use lrsched::sim::{
    CachePolicyChoice, ChurnConfig, Popularity, SimConfig, SimReport, Simulation, WorkloadConfig,
    WorkloadGen,
};
use lrsched::prop_assert;
use lrsched::testing::prop::{check, PropConfig};
use lrsched::util::units::{Bandwidth, Bytes};

/// A fleet of disk-starved edge nodes (2 GB — a handful of corpus images)
/// so kubelet GC actually churns the cache.
fn small_disk_nodes(n: u32) -> Vec<Node> {
    (0..n)
        .map(|i| {
            Node::new(
                NodeId(i),
                &format!("edge{:02}", i + 1),
                Resources::cores_gb(4.0, 8.0),
                Bytes::from_gb(2.0),
                Bandwidth::from_mbps(10.0),
            )
        })
        .collect()
}

/// Everything observable about a run: the full report plus the audit log.
fn fingerprint(report: &SimReport, sim: &Simulation) -> String {
    format!("{}\n---\n{}", report.render(), sim.events.render())
}

// ---------------------------------------------------------------------------
// PressureSweep vs. the pre-policy engine
// ---------------------------------------------------------------------------

/// Verbatim copy of the pre-policy `gc_images_node` eviction loop
/// (oldest-first insertion-order sweep), parameterized on the in-use
/// image list it derived from the pod table. The default `PressureSweep`
/// policy must reproduce it bit-for-bit on any node state.
fn reference_pressure_sweep(
    node: &mut Node,
    in_use: &[ImageRef],
    interner: &LayerInterner,
    images: &ImageLayerStore,
    free_target: Bytes,
) -> Bytes {
    let mut freed = Bytes::ZERO;
    loop {
        if node.disk_free() >= free_target {
            break;
        }
        // Oldest cached image not in use (images Vec is insertion-ordered).
        let victim = node.images.iter().find(|img| !in_use.contains(img)).cloned();
        let victim = match victim {
            Some(v) => v,
            None => break, // everything in use; cannot free more
        };
        let mut shared_with_others = LayerSet::new();
        for other in node.images.clone() {
            if other == victim {
                continue;
            }
            if let Some(set) = images.layers(&other) {
                shared_with_others.union_with(set);
            }
        }
        if let Some(victim_layers) = images.layers(&victim) {
            let unique: Vec<_> = victim_layers.difference_ids(&shared_with_others);
            freed += evict_layers_on(node, interner, &unique);
        }
        node.images.retain(|i| i != &victim);
    }
    freed
}

#[test]
fn pressure_sweep_matches_the_pre_policy_reference() {
    let cases = PropConfig::default();
    let cases = PropConfig { cases: cases.cases.clamp(24, 96), ..cases };
    check(cases, |rng, _| {
        // A random cached-image scenario on one disk-starved node: random
        // install order, random in-use subset, random use metadata (which
        // PressureSweep must ignore), random free target.
        let mut state = ClusterState::new();
        state.add_node(Node::new(
            NodeId(0),
            "edge01",
            Resources::cores_gb(8.0, 16.0),
            Bytes::from_mb(rng.f64_range(600.0, 3000.0)),
            Bandwidth::from_mbps(10.0),
        ));
        let corpus = hub::corpus();
        let mut images = ImageLayerStore::new();
        let mut installed: Vec<usize> = Vec::new();
        for _ in 0..rng.range(2, corpus.len()) {
            let idx = rng.range(0, corpus.len());
            let m = &corpus[idx];
            let (_, layers) = state.intern_image(m);
            if state.install_image(NodeId(0), &m.image_ref(), &layers).is_ok() {
                images.remember(&m.image_ref(), &layers);
                if !installed.contains(&idx) {
                    installed.push(idx);
                }
                // Scribble use metadata; the sweep must never read it.
                let t = rng.f64_range(0.0, 500.0);
                for l in layers.iter() {
                    state.node_mut(NodeId(0)).touch_layer(l, t, 300.0);
                }
            }
        }
        let mut builder = PodBuilder::new();
        let mut in_use: Vec<ImageRef> = Vec::new();
        for &idx in &installed {
            if rng.chance(0.4) {
                let m = &corpus[idx];
                let pod = builder
                    .build(&format!("{}:{}", m.name, m.tag), Resources::cores_gb(0.1, 0.1));
                let pid = state.submit_pod(pod);
                state.bind(pid, NodeId(0)).unwrap();
                in_use.push(m.image_ref());
            }
        }
        let free_target = Bytes::from_mb(rng.f64_range(0.0, 2500.0));

        let mut ref_node = state.node(NodeId(0)).clone();
        let ref_freed =
            reference_pressure_sweep(&mut ref_node, &in_use, &state.interner, &images, free_target);
        let freed = gc_images(
            &mut state,
            &images,
            NodeId(0),
            free_target,
            CachePolicyChoice::PressureSweep,
            rng.f64_range(1.0, 600.0), // decay must be irrelevant
            rng.f64_range(0.0, 1000.0), // and so must `now`
        );

        let node = state.node(NodeId(0));
        prop_assert!(
            freed == ref_freed,
            "freed bytes diverged from the pre-policy sweep: {} vs {} MB",
            freed.as_mb(),
            ref_freed.as_mb()
        );
        prop_assert!(node.images == ref_node.images, "surviving image list diverged");
        prop_assert!(
            node.layers.iter().collect::<Vec<_>>() == ref_node.layers.iter().collect::<Vec<_>>(),
            "surviving layer set diverged"
        );
        prop_assert!(
            node.disk_used == ref_node.disk_used,
            "disk accounting diverged: {} vs {} MB",
            node.disk_used.as_mb(),
            ref_node.disk_used.as_mb()
        );
        state.check_invariants().expect("cluster invariants");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Engine-level byte-identity
// ---------------------------------------------------------------------------

/// A 90-pod skewed workload on six disk-starved nodes with GC, the peer
/// swarm, and churn (a join, a drain, a crash, and a registry outage) all
/// on — the adversarial scenario every policy must survive unchanged
/// across shard counts and repeats. `policy: None` leaves the config at
/// its default (which must be `PressureSweep`).
fn churny_run(policy: Option<CachePolicyChoice>, shards: usize) -> (SimReport, String) {
    let registry = Registry::with_corpus();
    let wl = WorkloadConfig {
        seed: 61,
        popularity: Popularity::Zipf(1.2),
        duration_range: Some((15.0, 120.0)),
        ..Default::default()
    };
    let trace = WorkloadGen::new(&registry, wl).trace(90);
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(0.4);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 10;
    cfg.shards = shards;
    cfg.p2p_lan_mbps = Some(125.0);
    cfg.p2p_seeder_cap = 4;
    cfg.churn = Some(ChurnConfig {
        seed: 5,
        horizon_secs: 100.0,
        joins: 1,
        drains: 1,
        crash_fraction: 0.2,
        outages: 1,
        outage_secs: 15.0,
        ..Default::default()
    });
    if let Some(p) = policy {
        cfg.cache_policy = p;
    }
    let mut sim = Simulation::new(small_disk_nodes(6), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().expect("cluster invariants");
    let fp = fingerprint(&report, &sim);
    (report, fp)
}

#[test]
fn default_config_runs_the_pressure_sweep_policy() {
    assert_eq!(SimConfig::default().cache_policy, CachePolicyChoice::PressureSweep);
    let (_, implicit) = churny_run(None, 1);
    let (_, explicit) = churny_run(Some(CachePolicyChoice::PressureSweep), 1);
    assert!(
        implicit.contains("Evicted"),
        "the anchor scenario must exercise GC eviction to be meaningful"
    );
    assert!(
        implicit == explicit,
        "an untouched SimConfig must behave exactly like explicit PressureSweep"
    );
}

#[test]
fn every_policy_is_byte_identical_across_shards_and_repeats() {
    for policy in CachePolicyChoice::all() {
        let (report, seq) = churny_run(Some(policy), 1);
        let (_, par) = churny_run(Some(policy), 4);
        let (_, par2) = churny_run(Some(policy), 4);
        assert!(
            report.accounting_balanced(),
            "accounting identity violated under {policy:?}"
        );
        assert!(
            seq == par,
            "shards=4 diverged from sequential under {policy:?}\nfirst differing line: {:?}",
            seq.lines().zip(par.lines()).find(|(a, b)| a != b),
        );
        assert!(par == par2, "sharded run not reproducible under {policy:?}");
    }
}

// ---------------------------------------------------------------------------
// Hit-rate differential on a skewed workload
// ---------------------------------------------------------------------------

/// A Zipf-1.5 workload (a few images dominate arrivals) with short pod
/// lifetimes on disk-starved nodes: the cache churns constantly, so the
/// eviction order is what decides how many required bytes are already
/// local at bind time.
fn zipf_run(policy: CachePolicyChoice) -> SimReport {
    let registry = Registry::with_corpus();
    let wl = WorkloadConfig {
        seed: 99,
        popularity: Popularity::Zipf(1.5),
        duration_range: Some((5.0, 30.0)),
        ..Default::default()
    };
    let trace = WorkloadGen::new(&registry, wl).trace(600);
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(0.5);
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 50;
    cfg.cache_policy = policy;
    let mut sim = Simulation::new(small_disk_nodes(6), registry, cfg);
    let report = sim.run_trace(trace);
    sim.state.check_invariants().expect("cluster invariants");
    assert!(report.accounting_balanced(), "accounting identity violated under {policy:?}");
    report
}

#[test]
fn recency_and_popularity_beat_the_fixed_sweep_on_skewed_workloads() {
    let sweep = zipf_run(CachePolicyChoice::PressureSweep);
    assert!(
        sweep.evicted_bytes > Bytes::ZERO,
        "the scenario must actually evict for the policies to differ"
    );
    let lru = zipf_run(CachePolicyChoice::Lru);
    let pop = zipf_run(CachePolicyChoice::Popularity);
    assert!(
        lru.cache_hit_rate > sweep.cache_hit_rate,
        "LRU hit rate {:.4} must strictly beat the pressure sweep's {:.4}",
        lru.cache_hit_rate,
        sweep.cache_hit_rate
    );
    assert!(
        pop.cache_hit_rate > sweep.cache_hit_rate,
        "popularity hit rate {:.4} must strictly beat the pressure sweep's {:.4}",
        pop.cache_hit_rate,
        sweep.cache_hit_rate
    );
}

#[test]
fn prefetch_policy_warms_layers_and_stays_consistent() {
    let report = zipf_run(CachePolicyChoice::Prefetch);
    assert!(
        report.prefetched_bytes > Bytes::ZERO,
        "prefetch-on-intent never fired on a skewed workload"
    );
    assert!(
        (0.0..=1.0).contains(&report.cache_hit_rate),
        "hit rate {} out of range",
        report.cache_hit_rate
    );
}

#[test]
fn scorer_keep_set_policy_runs_clean_on_skewed_workloads() {
    let report = zipf_run(CachePolicyChoice::ScorerKeepSet);
    assert!(report.evicted_bytes > Bytes::ZERO, "scorer policy never evicted");
    assert!((0.0..=1.0).contains(&report.cache_hit_rate));
}
