//! Scheduling queue — FIFO of pending pods with a back-off parking lot for
//! unschedulable ones, a small analog of kube-scheduler's active/backoff
//! queues so the simulator can retry pods that failed filtering.
//!
//! Two release paths exist, mirroring kube-scheduler:
//! - **Timer** ([`SchedulingQueue::release_due`]): the classic back-off
//!   expiry, always armed as a fallback.
//! - **Wake-up** ([`SchedulingQueue::wake_capacity`]): a capacity-freeing
//!   cluster event (pod termination, image eviction, node join, registry
//!   outage end) immediately releases parked pods whose unschedulable
//!   reason it could cure — kube-scheduler's `QueueingHint` mechanism.
//!
//! Both paths release in FIFO order *by park time*. (An earlier version
//! used `swap_remove`, releasing same-deadline pods in arbitrary order,
//! which broke retry-order determinism once wake-ups released batches.)

use crate::cluster::PodId;
use std::collections::{BTreeMap, VecDeque};

/// What could cure a parked pod's unschedulable reason — kube-scheduler's
/// `QueueingHint` reduced to the two classes this simulator distinguishes.
/// `Ord` because the live-cure index keys a `BTreeMap` by cure class
/// (sorted keys: nothing hash-ordered can reach engine control flow).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum ParkCure {
    /// Freed capacity can cure it (resources, disk, container slots, or a
    /// node joining): released by capacity wake-ups *and* the timer.
    #[default]
    Capacity,
    /// Nothing the wake-up events model can cure (taints, affinity):
    /// released only by the back-off timer.
    Timer,
}

/// One parked pod. Entries live in park order, which is release order.
#[derive(Debug, Clone)]
struct Parked {
    pod: PodId,
    release_at: f64,
    cure: ParkCure,
}

/// The active/back-off queue pair.
#[derive(Debug, Clone, Default)]
pub struct SchedulingQueue {
    active: VecDeque<PodId>,
    /// Parked pods in FIFO park order.
    backoff: Vec<Parked>,
    /// Live-cure index: how many parked pods each cure class could
    /// release. Maintained by every park/release path so the sharded
    /// engine's cure-aware window collection reads it in O(log classes)
    /// instead of scanning the parking lot per window.
    cures: BTreeMap<ParkCure, usize>,
    /// Back-off applied by [`SchedulingQueue::park`].
    pub backoff_secs: f64,
}

impl SchedulingQueue {
    /// An empty queue with the 5-second default back-off.
    pub fn new() -> SchedulingQueue {
        SchedulingQueue {
            active: VecDeque::new(),
            backoff: Vec::new(),
            cures: BTreeMap::new(),
            backoff_secs: 5.0,
        }
    }

    /// Enqueue a pod for scheduling.
    pub fn push(&mut self, pod: PodId) {
        self.active.push_back(pod);
    }

    /// Next pod to schedule, if any.
    pub fn pop(&mut self) -> Option<PodId> {
        self.active.pop_front()
    }

    /// Park an unschedulable pod until `now + backoff_secs`; returns the
    /// release time so event-driven callers can schedule the release.
    /// Capacity wake-ups may release it earlier (see [`ParkCure`]).
    pub fn park(&mut self, pod: PodId, now: f64) -> f64 {
        self.park_with_cure(pod, now, ParkCure::Capacity)
    }

    /// [`SchedulingQueue::park`] with an explicit cure classification.
    pub fn park_with_cure(&mut self, pod: PodId, now: f64, cure: ParkCure) -> f64 {
        let release_at = now + self.backoff_secs;
        self.backoff.push(Parked { pod, release_at, cure });
        *self.cures.entry(cure).or_insert(0) += 1;
        release_at
    }

    /// Move every parked pod matching `pred` to the active queue, in FIFO
    /// order by park time (the shared core of both release paths).
    fn release_where(&mut self, pred: impl Fn(&Parked) -> bool) -> Vec<PodId> {
        let mut released = Vec::new();
        let active = &mut self.active;
        let cures = &mut self.cures;
        self.backoff.retain(|p| {
            if pred(p) {
                active.push_back(p.pod);
                released.push(p.pod);
                let c = cures.get_mut(&p.cure).expect("parked pod counted in cure index");
                *c -= 1;
                false
            } else {
                true
            }
        });
        released
    }

    /// Move pods whose back-off expired back to the active queue, in FIFO
    /// order by park time.
    pub fn release_due(&mut self, now: f64) -> usize {
        self.release_where(|p| p.release_at <= now).len()
    }

    /// Capacity wake-up: a capacity-freeing event occurred, so release every
    /// pod parked with [`ParkCure::Capacity`] immediately (FIFO by park
    /// time), ignoring its back-off deadline. Timer-only parks stay. Returns
    /// the released pods so the caller can grant them a free (uncharged)
    /// retry — wake-ups are opportunistic and must not burn the budget.
    pub fn wake_capacity(&mut self) -> Vec<PodId> {
        self.release_where(|p| p.cure == ParkCure::Capacity)
    }

    /// Earliest back-off expiry (for event-driven simulation).
    pub fn next_release_at(&self) -> Option<f64> {
        self.backoff
            .iter()
            .map(|p| p.release_at)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Nothing active and nothing parked?
    pub fn is_empty(&self) -> bool {
        self.active.is_empty() && self.backoff.is_empty()
    }

    /// Pods awaiting a scheduling cycle.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Pods parked in back-off.
    pub fn parked_len(&self) -> usize {
        self.backoff.len()
    }

    /// Parked pods a given cure class could release, from the live-cure
    /// index (O(log classes); no scan of the parking lot).
    pub fn parked_with(&self, cure: ParkCure) -> usize {
        self.cures.get(&cure).copied().unwrap_or(0)
    }

    /// Parked pods a capacity wake-up would release — exactly the number
    /// [`SchedulingQueue::wake_capacity`] would return pods for. The
    /// sharded engine's cure-aware window collection reads this once per
    /// window: zero means no node-local event in the window can wake
    /// anything, so the whole window is safe to run in parallel.
    pub fn capacity_parked(&self) -> usize {
        self.parked_with(ParkCure::Capacity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = SchedulingQueue::new();
        q.push(PodId(1));
        q.push(PodId(2));
        assert_eq!(q.pop(), Some(PodId(1)));
        assert_eq!(q.pop(), Some(PodId(2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn backoff_and_release() {
        let mut q = SchedulingQueue::new();
        assert_eq!(q.park(PodId(1), 0.0), 5.0);
        assert!(q.pop().is_none());
        assert_eq!(q.parked_len(), 1);
        assert_eq!(q.next_release_at(), Some(5.0));
        assert_eq!(q.release_due(4.9), 0);
        assert_eq!(q.release_due(5.0), 1);
        assert_eq!(q.pop(), Some(PodId(1)));
        assert!(q.is_empty());
    }

    #[test]
    fn multiple_backoffs_release_independently() {
        let mut q = SchedulingQueue::new();
        q.park(PodId(1), 0.0);
        q.park(PodId(2), 3.0);
        assert_eq!(q.release_due(5.0), 1);
        assert_eq!(q.parked_len(), 1);
        assert_eq!(q.release_due(8.0), 1);
    }

    #[test]
    fn same_deadline_batch_releases_fifo_by_park_time() {
        // Regression: swap_remove released same-deadline pods in arbitrary
        // order; batch releases must preserve park order.
        let mut q = SchedulingQueue::new();
        for pod in 0..8u64 {
            q.park(PodId(pod), 0.0); // all release at 5.0
        }
        assert_eq!(q.release_due(5.0), 8);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|p| p.0).collect();
        assert_eq!(order, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn wake_releases_capacity_parks_only_in_fifo_order() {
        let mut q = SchedulingQueue::new();
        q.park_with_cure(PodId(1), 0.0, ParkCure::Capacity);
        q.park_with_cure(PodId(2), 1.0, ParkCure::Timer);
        q.park_with_cure(PodId(3), 2.0, ParkCure::Capacity);
        assert_eq!(
            q.wake_capacity(),
            vec![PodId(1), PodId(3)],
            "only capacity-curable pods wake, in park order"
        );
        assert_eq!(q.pop(), Some(PodId(1)));
        assert_eq!(q.pop(), Some(PodId(3)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.parked_len(), 1, "timer-parked pod still waits");
        assert_eq!(q.release_due(6.0), 1);
        assert_eq!(q.pop(), Some(PodId(2)));
    }

    #[test]
    fn cure_index_tracks_every_park_and_release_path() {
        let mut q = SchedulingQueue::new();
        assert_eq!(q.capacity_parked(), 0);
        q.park_with_cure(PodId(1), 0.0, ParkCure::Capacity);
        q.park_with_cure(PodId(2), 0.0, ParkCure::Timer);
        q.park_with_cure(PodId(3), 0.0, ParkCure::Capacity);
        assert_eq!(q.capacity_parked(), 2);
        assert_eq!(q.parked_with(ParkCure::Timer), 1);
        // Wake drains the whole Capacity class from the index.
        assert_eq!(q.wake_capacity().len(), 2);
        assert_eq!(q.capacity_parked(), 0);
        assert_eq!(q.parked_with(ParkCure::Timer), 1);
        // The timer path decrements its class too.
        assert_eq!(q.release_due(5.0), 1);
        assert_eq!(q.parked_with(ParkCure::Timer), 0);
        // Re-parking after a release re-counts.
        q.park_with_cure(PodId(1), 10.0, ParkCure::Capacity);
        assert_eq!(q.capacity_parked(), 1);
        assert_eq!(q.release_due(15.0), 1);
        assert_eq!(q.capacity_parked(), 0);
    }

    #[test]
    fn cure_index_matches_a_parking_lot_scan() {
        // Property-style cross-check: after an arbitrary park/release
        // interleaving, the O(1) index equals what a scan would count
        // (here: zero remaining per class once everything released).
        let mut q = SchedulingQueue::new();
        let mut expect_cap = 0usize;
        for i in 0..50u64 {
            let cure = if i % 3 == 0 { ParkCure::Timer } else { ParkCure::Capacity };
            q.park_with_cure(PodId(i), i as f64, cure);
            if cure == ParkCure::Capacity {
                expect_cap += 1;
            }
            if i % 7 == 6 {
                expect_cap -= q.wake_capacity().len();
            }
            assert_eq!(q.capacity_parked(), expect_cap, "index drifted at step {i}");
            assert_eq!(
                q.capacity_parked() + q.parked_with(ParkCure::Timer),
                q.parked_len(),
                "classes must partition the parking lot"
            );
        }
        q.release_due(f64::MAX);
        assert_eq!(q.capacity_parked(), 0);
        assert_eq!(q.parked_with(ParkCure::Timer), 0);
        assert_eq!(q.parked_len(), 0);
    }

    #[test]
    fn wake_before_deadline_beats_timer() {
        let mut q = SchedulingQueue::new();
        let release_at = q.park(PodId(9), 10.0);
        assert_eq!(release_at, 15.0);
        // Capacity frees at t=11, well before the 15.0 deadline.
        assert_eq!(q.wake_capacity(), vec![PodId(9)]);
        assert_eq!(q.pop(), Some(PodId(9)));
        // The stale timer release later finds nothing to do.
        assert_eq!(q.release_due(15.0), 0);
    }
}
