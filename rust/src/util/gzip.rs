//! Gzip (RFC 1952) + DEFLATE (RFC 1951) decompression, dependency-free.
//!
//! The trace importer accepts `--trace foo.csv.gz`; real cluster traces
//! ship gzipped (Alibaba `batch_task.csv.gz` is ~2 GB compressed). The
//! crate is dependency-free by design (see `src/util/`), so instead of
//! pulling in `flate2` this module implements the inflate side of the
//! format directly: a bit-level reader, canonical-Huffman decoding (the
//! counting scheme from zlib's `puff`), all three block types, and the
//! CRC-32/ISIZE trailer checks.
//!
//! Decompression is **streaming**: [`GzDecoder`] wraps any
//! [`std::io::Read`] and implements `Read` itself, holding only a fixed
//! 32 KiB sliding window (the DEFLATE back-reference horizon), an 8 KiB
//! input buffer, and a small decode-ahead chunk — its memory footprint is
//! independent of both the compressed and the inflated size, so traces
//! larger than RAM stream straight through `BufRead::lines`.
//! Multi-member files (`cat a.gz b.gz`, pigz, bgzip) are supported, and
//! each member's CRC-32/ISIZE trailer is verified as the member
//! completes. The one-shot [`decompress`] convenience collects a whole
//! stream into a `Vec` for small inputs and tests.
//!
//! The write side is intentionally minimal: [`compress_stored`] emits a
//! valid single-member gzip file of *stored* (uncompressed) DEFLATE
//! blocks — enough for the bench/CI harnesses to generate multi-million
//! row `.csv.gz` traces without an external `gzip` binary, and readable
//! by any standards-compliant decoder.

use std::fmt;
use std::io::{self, Read};

/// Why a gzip stream failed to decompress.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GzipError {
    /// Input ended before the stream was complete.
    Truncated,
    /// The two-byte gzip magic (`1f 8b`) is missing.
    BadMagic,
    /// Structurally valid gzip, but a feature this decoder rejects
    /// (e.g. a compression method other than DEFLATE).
    Unsupported(&'static str),
    /// The DEFLATE stream is internally inconsistent.
    Corrupt(&'static str),
    /// The decompressed bytes do not match the stored CRC-32.
    CrcMismatch,
    /// The decompressed length does not match the stored ISIZE.
    SizeMismatch,
}

impl fmt::Display for GzipError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GzipError::Truncated => write!(f, "gzip stream truncated"),
            GzipError::BadMagic => write!(f, "not a gzip stream (bad magic)"),
            GzipError::Unsupported(what) => write!(f, "unsupported gzip feature: {what}"),
            GzipError::Corrupt(what) => write!(f, "corrupt deflate stream: {what}"),
            GzipError::CrcMismatch => write!(f, "gzip CRC-32 mismatch"),
            GzipError::SizeMismatch => write!(f, "gzip ISIZE mismatch"),
        }
    }
}

impl std::error::Error for GzipError {}

/// Internal failure channel: inner-reader I/O errors propagate verbatim,
/// format errors carry a [`GzipError`]. Converted to [`io::Error`] at the
/// `Read` boundary (the `GzipError` stays reachable via
/// [`io::Error::get_ref`] / `into_inner`).
enum Fail {
    Io(io::Error),
    Gz(GzipError),
}

impl From<GzipError> for Fail {
    fn from(g: GzipError) -> Fail {
        Fail::Gz(g)
    }
}

impl From<Fail> for io::Error {
    fn from(f: Fail) -> io::Error {
        match f {
            Fail::Io(e) => e,
            Fail::Gz(g) => {
                let kind = match g {
                    GzipError::Truncated => io::ErrorKind::UnexpectedEof,
                    _ => io::ErrorKind::InvalidData,
                };
                io::Error::new(kind, g)
            }
        }
    }
}

/// Incremental CRC-32 (IEEE 802.3, reflected, as gzip uses).
struct Crc32 {
    table: [u32; 256],
    state: u32,
}

impl Crc32 {
    fn new() -> Crc32 {
        let mut table = [0u32; 256];
        for (i, slot) in table.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *slot = c;
        }
        Crc32 { table, state: 0xFFFF_FFFF }
    }

    fn reset(&mut self) {
        self.state = 0xFFFF_FFFF;
    }

    fn update(&mut self, b: u8) {
        self.state = self.table[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
    }

    fn update_slice(&mut self, data: &[u8]) {
        for &b in data {
            self.update(b);
        }
    }

    fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// CRC-32 (IEEE 802.3, reflected, as gzip uses) of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = Crc32::new();
    for &b in data {
        crc.update(b);
    }
    crc.finish()
}

/// LSB-first bit reader over an inner [`Read`], with an 8 KiB refill
/// buffer. EOF mid-read surfaces as [`GzipError::Truncated`].
struct BitSource<R> {
    inner: R,
    buf: Vec<u8>,
    pos: usize,
    len: usize,
    bitbuf: u32,
    bitcnt: u32,
}

impl<R: Read> BitSource<R> {
    fn new(inner: R) -> BitSource<R> {
        BitSource { inner, buf: vec![0u8; 8192], pos: 0, len: 0, bitbuf: 0, bitcnt: 0 }
    }

    /// Refill the input buffer; returns the bytes read (0 = inner EOF).
    fn refill(&mut self) -> Result<usize, Fail> {
        self.pos = 0;
        self.len = 0;
        loop {
            match self.inner.read(&mut self.buf) {
                Ok(n) => {
                    self.len = n;
                    return Ok(n);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(Fail::Io(e)),
            }
        }
    }

    /// Next raw input byte, or `None` at a clean inner EOF.
    fn next_byte_opt(&mut self) -> Result<Option<u8>, Fail> {
        if self.pos >= self.len && self.refill()? == 0 {
            return Ok(None);
        }
        let b = self.buf[self.pos];
        self.pos += 1;
        Ok(Some(b))
    }

    /// Next raw input byte; EOF is [`GzipError::Truncated`].
    fn need_byte(&mut self) -> Result<u8, Fail> {
        self.next_byte_opt()?.ok_or(Fail::Gz(GzipError::Truncated))
    }

    /// Read `n <= 16` bits, LSB-first.
    fn bits(&mut self, n: u32) -> Result<u32, Fail> {
        while self.bitcnt < n {
            let byte = self.need_byte()? as u32;
            self.bitbuf |= byte << self.bitcnt;
            self.bitcnt += 8;
        }
        let v = self.bitbuf & ((1u32 << n) - 1);
        self.bitbuf >>= n;
        self.bitcnt -= n;
        Ok(v)
    }

    /// Discard the partial byte (stored blocks and trailers start
    /// byte-aligned). At most 7 bits are ever buffered after a `bits`
    /// call, so this never loses a whole byte.
    fn align_byte(&mut self) {
        debug_assert!(self.bitcnt < 8, "a whole byte was buffered");
        self.bitbuf = 0;
        self.bitcnt = 0;
    }

    /// Read one raw byte (caller must be byte-aligned).
    fn aligned_byte(&mut self) -> Result<u8, Fail> {
        debug_assert_eq!(self.bitcnt, 0, "byte read while unaligned");
        self.need_byte()
    }
}

/// A canonical Huffman code in the count/symbol form of zlib's `puff`:
/// `counts[l]` codes of length `l`, symbols sorted by (length, symbol).
struct Huffman {
    counts: [u16; 16],
    symbols: Vec<u16>,
}

impl Huffman {
    fn build(lengths: &[u8]) -> Result<Huffman, GzipError> {
        let mut counts = [0u16; 16];
        for &l in lengths {
            if l > 15 {
                return Err(GzipError::Corrupt("code length > 15"));
            }
            counts[l as usize] += 1;
        }
        counts[0] = 0;
        // Reject over-subscribed codes (incomplete ones are legal: a
        // single-distance-code block uses one).
        let mut left: i32 = 1;
        for len in 1..16 {
            left <<= 1;
            left -= counts[len] as i32;
            if left < 0 {
                return Err(GzipError::Corrupt("oversubscribed huffman code"));
            }
        }
        let mut offs = [0u16; 16];
        for len in 1..15 {
            offs[len + 1] = offs[len] + counts[len];
        }
        let n_symbols = lengths.iter().filter(|&&l| l != 0).count();
        let mut symbols = vec![0u16; n_symbols];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbols[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { counts, symbols })
    }

    /// Decode one symbol, one bit at a time (adequate for trace-sized
    /// inputs; a table-driven fast path can come later if profiles ask).
    fn decode<R: Read>(&self, br: &mut BitSource<R>) -> Result<u16, Fail> {
        let mut code: u32 = 0;
        let mut first: u32 = 0;
        let mut index: u32 = 0;
        for len in 1..16 {
            code |= br.bits(1)?;
            let count = self.counts[len] as u32;
            if code < first + count {
                return Ok(self.symbols[(index + (code - first)) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err(Fail::Gz(GzipError::Corrupt("invalid huffman code")))
    }
}

const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

/// DEFLATE sliding-window size: distances never reach further back.
const WINDOW: usize = 32 * 1024;
const WINDOW_MASK: usize = WINDOW - 1;
/// Decode-ahead target per `step`: once this much output is pending the
/// decoder yields to the caller, bounding the pending buffer at
/// `OUT_TARGET + 258` (the longest match can overshoot by one copy).
const OUT_TARGET: usize = 32 * 1024;

/// Where the decode state machine stands between `read` calls.
enum State {
    /// Before a member header: expect EOF (if at least one member has
    /// completed) or the next `1f 8b` magic.
    Member,
    /// Inside a member, before a block header.
    BlockStart,
    /// Copying a stored block's raw bytes.
    Stored {
        /// Bytes left in the block.
        remaining: usize,
        /// Was this the member's final block?
        last: bool,
    },
    /// Decoding a fixed- or dynamic-Huffman block.
    Compressed {
        /// Literal/length code.
        litlen: Huffman,
        /// Distance code.
        dist: Huffman,
        /// Was this the member's final block?
        last: bool,
    },
    /// Reading + verifying the member's CRC-32/ISIZE trailer.
    Trailer,
    /// Clean end of the final member.
    Done,
    /// A previous step failed; all further reads fail.
    Poisoned,
}

/// Streaming gzip decoder over any [`Read`] — see the module docs for
/// the memory-footprint guarantee. Trailing garbage after the final
/// member is an error (it must be another member), matching
/// [`decompress`].
///
/// ```
/// use lrsched::util::gzip::{compress_stored, GzDecoder};
/// use std::io::Read;
/// let gz = compress_stored(b"hello streaming world");
/// let mut out = Vec::new();
/// GzDecoder::new(&gz[..]).read_to_end(&mut out).unwrap();
/// assert_eq!(out, b"hello streaming world");
/// ```
pub struct GzDecoder<R> {
    bits: BitSource<R>,
    state: State,
    /// Circular 32 KiB back-reference window.
    window: Vec<u8>,
    win_pos: usize,
    win_len: usize,
    /// Decoded bytes not yet handed to the caller.
    out: Vec<u8>,
    out_pos: usize,
    crc: Crc32,
    /// Current member's output length mod 2^32 (ISIZE semantics).
    member_len: u32,
    members_done: u64,
}

impl<R: Read> GzDecoder<R> {
    /// Wrap `inner` (the raw `.gz` byte stream) in a streaming decoder.
    pub fn new(inner: R) -> GzDecoder<R> {
        GzDecoder {
            bits: BitSource::new(inner),
            state: State::Member,
            window: vec![0u8; WINDOW],
            win_pos: 0,
            win_len: 0,
            out: Vec::with_capacity(OUT_TARGET + 300),
            out_pos: 0,
            crc: Crc32::new(),
            member_len: 0,
            members_done: 0,
        }
    }

    /// Gzip members fully decoded and trailer-verified so far.
    pub fn members_done(&self) -> u64 {
        self.members_done
    }

    /// Append one decoded byte to the pending output, the window, and the
    /// member's CRC/length accumulators.
    fn emit(&mut self, b: u8) {
        self.out.push(b);
        self.crc.update(b);
        self.member_len = self.member_len.wrapping_add(1);
        self.window[self.win_pos] = b;
        self.win_pos = (self.win_pos + 1) & WINDOW_MASK;
        if self.win_len < WINDOW {
            self.win_len += 1;
        }
    }

    /// Bulk [`GzDecoder::emit`]: one `extend` + batched CRC + at most two
    /// window copies (wrap-around). `data.len()` must not exceed the
    /// window — callers emit at most one input buffer per call. Stored
    /// blocks take this path; later blocks in the same member may
    /// back-reference the copied bytes, so the window must see them too.
    fn emit_slice(&mut self, data: &[u8]) {
        debug_assert!(data.len() <= WINDOW, "bulk emit larger than the window");
        self.out.extend_from_slice(data);
        self.crc.update_slice(data);
        self.member_len = self.member_len.wrapping_add(data.len() as u32);
        let n = data.len();
        let first = n.min(WINDOW - self.win_pos);
        self.window[self.win_pos..self.win_pos + first].copy_from_slice(&data[..first]);
        if first < n {
            self.window[..n - first].copy_from_slice(&data[first..]);
        }
        self.win_pos = (self.win_pos + n) & WINDOW_MASK;
        self.win_len = (self.win_len + n).min(WINDOW);
    }

    /// Replay a back-reference of `len` bytes from `dist` back.
    /// Byte-by-byte so overlapping copies replicate recent output.
    fn copy_match(&mut self, dist: usize, len: usize) -> Result<(), Fail> {
        if dist == 0 || dist > self.win_len {
            return Err(Fail::Gz(GzipError::Corrupt("distance beyond window")));
        }
        let mut src = (self.win_pos + WINDOW - dist) & WINDOW_MASK;
        for _ in 0..len {
            let b = self.window[src];
            src = (src + 1) & WINDOW_MASK;
            self.emit(b);
        }
        Ok(())
    }

    /// How many decoded bytes await the caller.
    fn pending(&self) -> usize {
        self.out.len() - self.out_pos
    }

    /// Parse one member header (the magic has already been matched).
    fn read_header_rest(&mut self) -> Result<(), Fail> {
        if self.bits.need_byte()? != 8 {
            return Err(Fail::Gz(GzipError::Unsupported("compression method is not DEFLATE")));
        }
        let flg = self.bits.need_byte()?;
        for _ in 0..6 {
            self.bits.need_byte()?; // MTIME(4) + XFL + OS
        }
        if flg & 0x04 != 0 {
            // FEXTRA: u16-le length + payload.
            let lo = self.bits.need_byte()? as usize;
            let hi = self.bits.need_byte()? as usize;
            for _ in 0..(lo | (hi << 8)) {
                self.bits.need_byte()?;
            }
        }
        for flag in [0x08u8, 0x10] {
            // FNAME / FCOMMENT: NUL-terminated strings.
            if flg & flag != 0 {
                while self.bits.need_byte()? != 0 {}
            }
        }
        if flg & 0x02 != 0 {
            self.bits.need_byte()?; // FHCRC (2 bytes, not verified)
            self.bits.need_byte()?;
        }
        Ok(())
    }

    /// Read a block header and build its tables (or set up the stored
    /// copy). Returns the state the block body decodes under.
    fn begin_block(&mut self) -> Result<State, Fail> {
        let last = self.bits.bits(1)? == 1;
        let btype = self.bits.bits(2)?;
        match btype {
            0 => {
                // Stored: byte-aligned LEN/NLEN + raw copy.
                self.bits.align_byte();
                let len =
                    self.bits.aligned_byte()? as usize | ((self.bits.aligned_byte()? as usize) << 8);
                let nlen =
                    self.bits.aligned_byte()? as usize | ((self.bits.aligned_byte()? as usize) << 8);
                if len ^ nlen != 0xFFFF {
                    return Err(Fail::Gz(GzipError::Corrupt("stored-block length check")));
                }
                Ok(State::Stored { remaining: len, last })
            }
            1 => {
                // Fixed Huffman tables (RFC 1951 §3.2.6).
                let mut litlen_lens = [0u8; 288];
                for (i, l) in litlen_lens.iter_mut().enumerate() {
                    *l = match i {
                        0..=143 => 8,
                        144..=255 => 9,
                        256..=279 => 7,
                        _ => 8,
                    };
                }
                let litlen = Huffman::build(&litlen_lens)?;
                let dist = Huffman::build(&[5u8; 30])?;
                Ok(State::Compressed { litlen, dist, last })
            }
            2 => {
                // Dynamic tables: code-length code, then the two codes.
                let hlit = self.bits.bits(5)? as usize + 257;
                let hdist = self.bits.bits(5)? as usize + 1;
                let hclen = self.bits.bits(4)? as usize + 4;
                const ORDER: [usize; 19] =
                    [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];
                let mut cl_lens = [0u8; 19];
                for &slot in ORDER.iter().take(hclen) {
                    cl_lens[slot] = self.bits.bits(3)? as u8;
                }
                let cl = Huffman::build(&cl_lens)?;
                let mut lens = vec![0u8; hlit + hdist];
                let mut i = 0;
                while i < lens.len() {
                    let sym = cl.decode(&mut self.bits)?;
                    match sym {
                        0..=15 => {
                            lens[i] = sym as u8;
                            i += 1;
                        }
                        16 | 17 | 18 => {
                            let (fill, rep) = match sym {
                                16 => {
                                    if i == 0 {
                                        return Err(Fail::Gz(GzipError::Corrupt(
                                            "length repeat with no previous length",
                                        )));
                                    }
                                    (lens[i - 1], 3 + self.bits.bits(2)? as usize)
                                }
                                17 => (0, 3 + self.bits.bits(3)? as usize),
                                _ => (0, 11 + self.bits.bits(7)? as usize),
                            };
                            if i + rep > lens.len() {
                                return Err(Fail::Gz(GzipError::Corrupt("too many code lengths")));
                            }
                            for slot in lens.iter_mut().skip(i).take(rep) {
                                *slot = fill;
                            }
                            i += rep;
                        }
                        _ => {
                            return Err(Fail::Gz(GzipError::Corrupt(
                                "invalid code-length symbol",
                            )))
                        }
                    }
                }
                if lens[256] == 0 {
                    return Err(Fail::Gz(GzipError::Corrupt("missing end-of-block code")));
                }
                let litlen = Huffman::build(&lens[..hlit])?;
                let dist = Huffman::build(&lens[hlit..])?;
                Ok(State::Compressed { litlen, dist, last })
            }
            _ => Err(Fail::Gz(GzipError::Corrupt("reserved block type"))),
        }
    }

    /// Advance the state machine: parse a header, decode up to
    /// [`OUT_TARGET`] bytes of block body, or verify a trailer. Each call
    /// makes progress; `read` loops until output is pending or the stream
    /// is done.
    fn step(&mut self) -> Result<(), Fail> {
        let state = std::mem::replace(&mut self.state, State::Poisoned);
        match state {
            State::Member => {
                match self.bits.next_byte_opt()? {
                    None => {
                        if self.members_done == 0 {
                            // Empty input is a truncated stream, not EOF.
                            return Err(Fail::Gz(GzipError::Truncated));
                        }
                        self.state = State::Done;
                        return Ok(());
                    }
                    Some(b1) => {
                        let b2 = match self.bits.next_byte_opt()? {
                            None => return Err(Fail::Gz(GzipError::Truncated)),
                            Some(b) => b,
                        };
                        if b1 != 0x1f || b2 != 0x8b {
                            return Err(Fail::Gz(GzipError::BadMagic));
                        }
                    }
                }
                self.read_header_rest()?;
                self.crc.reset();
                self.member_len = 0;
                // Each member is an independent DEFLATE stream: distances
                // cannot reach past its start.
                self.win_pos = 0;
                self.win_len = 0;
                self.state = State::BlockStart;
            }
            State::BlockStart => {
                self.state = self.begin_block()?;
            }
            State::Stored { mut remaining, last } => {
                // Bulk copy straight out of the input buffer (the body is
                // byte-aligned raw data): one refill + one slice emit per
                // buffered run instead of per-byte calls.
                debug_assert_eq!(self.bits.bitcnt, 0, "stored body read while unaligned");
                while remaining > 0 {
                    if self.pending() >= OUT_TARGET {
                        self.state = State::Stored { remaining, last };
                        return Ok(());
                    }
                    if self.bits.pos >= self.bits.len && self.bits.refill()? == 0 {
                        return Err(Fail::Gz(GzipError::Truncated));
                    }
                    let take = remaining.min(self.bits.len - self.bits.pos);
                    let start = self.bits.pos;
                    self.bits.pos += take;
                    // Temporarily take the input buffer so `emit_slice`
                    // can borrow self mutably (no extra copy; emit_slice
                    // cannot fail, so the buffer is always restored).
                    let buf = std::mem::take(&mut self.bits.buf);
                    self.emit_slice(&buf[start..start + take]);
                    self.bits.buf = buf;
                    remaining -= take;
                }
                self.state = if last { State::Trailer } else { State::BlockStart };
            }
            State::Compressed { litlen, dist, last } => {
                loop {
                    if self.pending() >= OUT_TARGET {
                        self.state = State::Compressed { litlen, dist, last };
                        return Ok(());
                    }
                    let sym = litlen.decode(&mut self.bits)?;
                    if sym < 256 {
                        self.emit(sym as u8);
                    } else if sym == 256 {
                        self.state = if last { State::Trailer } else { State::BlockStart };
                        return Ok(());
                    } else {
                        let idx = (sym - 257) as usize;
                        if idx >= LEN_BASE.len() {
                            return Err(Fail::Gz(GzipError::Corrupt("invalid length symbol")));
                        }
                        let len =
                            LEN_BASE[idx] as usize + self.bits.bits(LEN_EXTRA[idx] as u32)? as usize;
                        let dsym = dist.decode(&mut self.bits)? as usize;
                        if dsym >= DIST_BASE.len() {
                            return Err(Fail::Gz(GzipError::Corrupt("invalid distance symbol")));
                        }
                        let d = DIST_BASE[dsym] as usize
                            + self.bits.bits(DIST_EXTRA[dsym] as u32)? as usize;
                        self.copy_match(d, len)?;
                    }
                }
            }
            State::Trailer => {
                // CRC-32 then ISIZE (mod 2^32), little-endian, at the next
                // byte boundary (at most 7 bits are dropped).
                self.bits.align_byte();
                let mut t = [0u8; 8];
                for slot in &mut t {
                    *slot = self.bits.aligned_byte()?;
                }
                let crc = u32::from_le_bytes([t[0], t[1], t[2], t[3]]);
                let isize_ = u32::from_le_bytes([t[4], t[5], t[6], t[7]]);
                if self.crc.finish() != crc {
                    return Err(Fail::Gz(GzipError::CrcMismatch));
                }
                if self.member_len != isize_ {
                    return Err(Fail::Gz(GzipError::SizeMismatch));
                }
                self.members_done += 1;
                // Anything after a trailer must be another member (its
                // magic is re-checked); trailing garbage errors.
                self.state = State::Member;
            }
            State::Done => {
                self.state = State::Done;
            }
            State::Poisoned => {
                return Err(Fail::Gz(GzipError::Corrupt("read after a decode error")));
            }
        }
        Ok(())
    }
}

impl<R: Read> Read for GzDecoder<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        loop {
            let avail = self.pending();
            if avail > 0 {
                let n = avail.min(buf.len());
                buf[..n].copy_from_slice(&self.out[self.out_pos..self.out_pos + n]);
                self.out_pos += n;
                if self.out_pos == self.out.len() {
                    self.out.clear();
                    self.out_pos = 0;
                }
                return Ok(n);
            }
            if matches!(self.state, State::Done) {
                return Ok(0);
            }
            self.step().map_err(io::Error::from)?;
        }
    }
}

/// Extract the [`GzipError`] a failed [`GzDecoder`] read carries (inner
/// I/O errors map to [`GzipError::Truncated`] only when the kind says
/// EOF; anything else is reported as corrupt).
fn unwrap_gzip_err(e: io::Error) -> GzipError {
    match e.into_inner().and_then(|b| b.downcast::<GzipError>().ok()) {
        Some(g) => *g,
        None => GzipError::Corrupt("i/o error reading gzip stream"),
    }
}

/// Decompress a whole gzip file in memory: one or more concatenated
/// members (RFC 1952 §2.2 — `cat a.gz b.gz`, pigz, and bgzip all produce
/// multi-member files), each a header + DEFLATE body + CRC-32/ISIZE
/// trailer, both trailer fields verified per member. This is the
/// buffered convenience over the streaming [`GzDecoder`] — large traces
/// should wrap the decoder directly instead of collecting a `Vec`.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>, GzipError> {
    let mut dec = GzDecoder::new(data);
    let mut out = Vec::with_capacity(data.len().saturating_mul(3));
    let mut chunk = [0u8; 16 * 1024];
    loop {
        match dec.read(&mut chunk) {
            Ok(0) => return Ok(out),
            Ok(n) => out.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(unwrap_gzip_err(e)),
        }
    }
}

/// Emit `data` as a valid single-member gzip file of *stored*
/// (uncompressed) DEFLATE blocks — no compression, ~0.008% framing
/// overhead, readable by any decoder. The bench/CI harnesses use this to
/// generate large `.csv.gz` traces without an external `gzip` binary;
/// the output is deterministic (zeroed MTIME, OS = unknown).
pub fn compress_stored(data: &[u8]) -> Vec<u8> {
    // Header + one 5-byte block frame per 65 535-byte chunk + trailer.
    let mut out = Vec::with_capacity(data.len() + data.len() / 65_535 * 5 + 32);
    out.extend_from_slice(&[0x1f, 0x8b, 0x08, 0x00, 0x00, 0x00, 0x00, 0x00, 0x00, 0xff]);
    if data.is_empty() {
        // A final stored block of length 0.
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xff, 0xff]);
    } else {
        let mut chunks = data.chunks(65_535).peekable();
        while let Some(chunk) = chunks.next() {
            let last = chunks.peek().is_none();
            out.push(if last { 0x01 } else { 0x00 });
            let len = chunk.len() as u16;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(chunk);
        }
    }
    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A handcrafted gzip member: one stored block holding "hello".
    fn hello_gz() -> Vec<u8> {
        let mut v = vec![
            0x1f, 0x8b, 0x08, 0x00, // magic, deflate, no flags
            0x00, 0x00, 0x00, 0x00, // mtime = 0
            0x00, 0x03, // xfl, os = unix
            0x01, // bfinal=1, btype=00 (stored)
            0x05, 0x00, 0xfa, 0xff, // LEN=5, NLEN=!5
        ];
        v.extend_from_slice(b"hello");
        v.extend_from_slice(&0x3610_a686u32.to_le_bytes()); // crc32("hello")
        v.extend_from_slice(&5u32.to_le_bytes()); // isize
        v
    }

    #[test]
    fn stored_block_roundtrip() {
        assert_eq!(decompress(&hello_gz()).unwrap(), b"hello");
    }

    #[test]
    fn multi_member_files_concatenate() {
        // RFC 1952 §2.2: a gzip file is a *series* of members
        // (`cat a.gz b.gz`, pigz, bgzip). All members must inflate, each
        // with its own verified trailer.
        let mut two = hello_gz();
        two.extend_from_slice(&hello_gz());
        assert_eq!(decompress(&two).unwrap(), b"hellohello");
        // Trailing garbage after the last member is an error, not silence.
        let mut garbage = hello_gz();
        garbage.extend_from_slice(b"tail");
        assert!(decompress(&garbage).is_err());
    }

    #[test]
    fn real_deflate_fixture_roundtrip() {
        // Produced by Python's gzip (dynamic-Huffman blocks) from the
        // bundled Alibaba fixture; must inflate to the exact plain bytes.
        let gz = include_bytes!("../../tests/fixtures/alibaba_mini.csv.gz");
        let plain = include_bytes!("../../tests/fixtures/alibaba_mini.csv");
        assert_eq!(decompress(gz).unwrap(), plain);
    }

    #[test]
    fn crc_detects_payload_corruption() {
        let mut gz = hello_gz();
        let idx = gz.len() - 9; // last payload byte ("o")
        gz[idx] ^= 0x20;
        assert_eq!(decompress(&gz), Err(GzipError::CrcMismatch));
    }

    #[test]
    fn truncation_and_magic_errors() {
        assert_eq!(decompress(&[]), Err(GzipError::Truncated));
        assert_eq!(decompress(&[0x1f, 0x8b, 0x08]), Err(GzipError::Truncated));
        assert_eq!(decompress(b"plain,csv,data"), Err(GzipError::BadMagic));
        let mut gz = hello_gz();
        gz.truncate(gz.len() - 4);
        assert_eq!(decompress(&gz), Err(GzipError::Truncated));
    }

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"hello"), 0x3610_a686);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    // --- streaming-decoder tests ------------------------------------------

    /// Drain a decoder through `read` calls capped at `chunk` bytes,
    /// exercising mid-member suspension/resume.
    fn read_chunked<R: Read>(mut dec: GzDecoder<R>, chunk: usize) -> io::Result<Vec<u8>> {
        let mut out = Vec::new();
        let mut buf = vec![0u8; chunk];
        loop {
            match dec.read(&mut buf)? {
                0 => return Ok(out),
                n => out.extend_from_slice(&buf[..n]),
            }
        }
    }

    #[test]
    fn streaming_chunked_reads_match_one_shot() {
        let gz = include_bytes!("../../tests/fixtures/alibaba_mini.csv.gz");
        let plain = include_bytes!("../../tests/fixtures/alibaba_mini.csv");
        // 1-byte reads force suspension at every possible decode point.
        for chunk in [1usize, 7, 4096] {
            let out = read_chunked(GzDecoder::new(&gz[..]), chunk).unwrap();
            assert_eq!(out, plain, "chunk size {chunk}");
        }
    }

    /// A reader that hands out its data one byte per `read` call — the
    /// worst-case inner source (mid-everything input boundaries).
    struct OneByte<'a>(&'a [u8]);

    impl Read for OneByte<'_> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            match self.0.split_first() {
                None => Ok(0),
                Some((b, rest)) => {
                    self.0 = rest;
                    buf[0] = *b;
                    Ok(1)
                }
            }
        }
    }

    #[test]
    fn streaming_survives_one_byte_inner_reads() {
        let gz = include_bytes!("../../tests/fixtures/alibaba_mini.csv.gz");
        let plain = include_bytes!("../../tests/fixtures/alibaba_mini.csv");
        let out = read_chunked(GzDecoder::new(OneByte(gz)), 513).unwrap();
        assert_eq!(out, plain);
    }

    #[test]
    fn streaming_multi_member_and_member_count() {
        let mut three = hello_gz();
        three.extend_from_slice(&compress_stored(b" world"));
        three.extend_from_slice(&hello_gz());
        let mut dec = GzDecoder::new(&three[..]);
        let mut out = Vec::new();
        dec.read_to_end(&mut out).unwrap();
        assert_eq!(out, b"hello worldhello");
        assert_eq!(dec.members_done(), 3);
    }

    #[test]
    fn streaming_truncated_stream_is_unexpected_eof() {
        let mut gz = hello_gz();
        gz.truncate(gz.len() - 6); // inside the payload
        let err = read_chunked(GzDecoder::new(&gz[..]), 64).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        assert_eq!(unwrap_gzip_err(err), GzipError::Truncated);
    }

    #[test]
    fn streaming_crc_corruption_is_invalid_data() {
        let mut gz = hello_gz();
        let idx = gz.len() - 9;
        gz[idx] ^= 0x20;
        let err = read_chunked(GzDecoder::new(&gz[..]), 64).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert_eq!(unwrap_gzip_err(err), GzipError::CrcMismatch);
    }

    #[test]
    fn compress_stored_roundtrips() {
        // Empty, small, and > 64 KiB (multiple stored blocks; the payload
        // also exercises window wrap-around on the decode side). Under
        // Miri the payload shrinks — still past the 65 535-byte stored
        // block cap, so the multi-block path runs, just interpretably so.
        let big_len: u32 = if cfg!(miri) { 70_000 } else { 200_000 };
        let big: Vec<u8> = (0..big_len).map(|i| (i % 251) as u8).collect();
        for data in [&b""[..], &b"x"[..], &b"hello stored world"[..], &big[..]] {
            let gz = compress_stored(data);
            assert_eq!(decompress(&gz).unwrap(), data, "len {}", data.len());
            // And through chunked streaming reads.
            let out = read_chunked(GzDecoder::new(&gz[..]), 1000).unwrap();
            assert_eq!(out, data);
        }
    }

    #[test]
    fn compress_stored_is_tamper_evident() {
        let mut gz = compress_stored(b"abcdefgh");
        let idx = gz.len() - 9; // last payload byte
        gz[idx] ^= 0x01;
        assert_eq!(decompress(&gz), Err(GzipError::CrcMismatch));
    }
}
