//! Scalability of LRScheduler (paper §IV-B): the layer-sharing score
//! composes with any plugin subset and any ω policy. This example sweeps
//! both axes on the same trace.
//!
//! Run: `cargo run --release --example combined_schedulers`

use lrsched::exp::common;
use lrsched::registry::Registry;
use lrsched::sched::{FrameworkConfig, WeightParams};
use lrsched::sim::{SchedulerChoice, SimConfig, Simulation};

fn run_with(
    trace: &[lrsched::cluster::Pod],
    label: &str,
    framework: FrameworkConfig,
    params: WeightParams,
) {
    let mut cfg = SimConfig::default();
    cfg.scheduler = SchedulerChoice::LR;
    cfg.framework = framework;
    cfg.params = params;
    let mut sim = Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg);
    let rep = sim.run_trace(trace.to_vec());
    println!(
        "{label:<44} dl {:>8.1} MB   STD {:.3}   w1/w2 {:>2}/{:<2}",
        rep.total_download().as_mb(),
        rep.final_std(),
        rep.omega1_used,
        rep.omega2_used
    );
}

fn run_choice(trace: &[lrsched::cluster::Pod], label: &str, choice: SchedulerChoice, p2p: Option<f64>) {
    let mut cfg = SimConfig::default();
    cfg.scheduler = choice;
    cfg.p2p_lan_mbps = p2p;
    let mut sim = Simulation::new(common::paper_nodes(4), Registry::with_corpus(), cfg);
    let rep = sim.run_trace(trace.to_vec());
    let p2p_mb: f64 = rep.records.iter().map(|r| r.p2p.as_mb()).sum();
    println!(
        "{label:<44} dl {:>8.1} MB   STD {:.3}   p2p {:>7.1} MB",
        rep.total_download().as_mb(),
        rep.final_std(),
        p2p_mb
    );
}

fn main() {
    let trace = common::paper_trace(42, 20);
    let p = WeightParams::default();

    println!("--- plugin-subset ablation (LR on top of each profile) ---");
    run_with(&trace, "full default profile (8 plugins)", FrameworkConfig::default(), p);
    run_with(&trace, "resources only (LeastAllocated+Balanced)", FrameworkConfig::resources_only(), p);
    let mut no_img = FrameworkConfig::default();
    no_img.image_locality = false;
    run_with(&trace, "without ImageLocality", no_img, p);
    let mut no_balance = FrameworkConfig::default();
    no_balance.balanced_allocation = false;
    run_with(&trace, "without BalancedAllocation", no_balance, p);

    println!("\n--- omega parameter ablation (paper h/omega settings) ---");
    run_with(&trace, "paper: w1=2 w2=0.5", FrameworkConfig::default(), p);
    run_with(
        &trace,
        "aggressive: w1=4 w2=1",
        FrameworkConfig::default(),
        WeightParams { omega1: 4.0, omega2: 1.0, ..p },
    );
    run_with(
        &trace,
        "conservative: w1=1 w2=0.1",
        FrameworkConfig::default(),
        WeightParams { omega1: 1.0, omega2: 0.1, ..p },
    );
    run_with(
        &trace,
        "tight gate: h_cpu=0.3 h_std=0.08",
        FrameworkConfig::default(),
        WeightParams { h_cpu: 0.3, h_std: 0.08, ..p },
    );

    println!("\n--- paper SVII extensions ---");
    run_choice(&trace, "RL scheduler (contextual bandit)", SchedulerChoice::Rl, None);
    run_choice(&trace, "LRScheduler + P2P layer sharing (100 MB/s LAN)", SchedulerChoice::LR, Some(100.0));
    run_choice(&trace, "Default + P2P layer sharing", SchedulerChoice::Default, Some(100.0));
}
