//! Image layers: identities, sizes, and the layer-set algebra used by the
//! layer-sharing score (paper Eqs. 1–3).
//!
//! Layers are content-addressed (`sha256:` digests in real registries); the
//! scheduler never looks inside a layer, only at (digest, size). For hot-path
//! set operations the crate interns digests into dense `LayerId`s and stores
//! per-node layer inventories as bitsets (`LayerSet`).

use crate::util::units::Bytes;
use std::collections::HashMap;

/// Dense interned layer identity, valid within one [`LayerInterner`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerId(pub u32);

/// Digest + size as stored in a registry manifest (paper Listing 1,
/// `LayerMetadata { Size, Layer }`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerMetadata {
    /// Content digest, e.g. `sha256:8f4e…`.
    pub digest: String,
    /// Compressed layer size.
    pub size: Bytes,
}

/// Interns layer digests to dense ids and remembers their sizes.
///
/// One interner is shared by the registry, the cluster state, and the
/// scheduler so that `LayerId`s are comparable everywhere.
#[derive(Debug, Default, Clone)]
pub struct LayerInterner {
    by_digest: HashMap<String, LayerId>,
    digests: Vec<String>,
    sizes: Vec<Bytes>,
}

impl LayerInterner {
    /// An empty interner.
    pub fn new() -> LayerInterner {
        LayerInterner::default()
    }

    /// Intern a digest, recording its size on first sight. Re-interning with
    /// a different size is a registry inconsistency and panics in debug
    /// builds (content-addressed layers cannot change size).
    pub fn intern(&mut self, digest: &str, size: Bytes) -> LayerId {
        if let Some(&id) = self.by_digest.get(digest) {
            debug_assert_eq!(
                self.sizes[id.0 as usize], size,
                "layer {digest} re-interned with different size"
            );
            return id;
        }
        let id = LayerId(self.digests.len() as u32);
        self.by_digest.insert(digest.to_string(), id);
        self.digests.push(digest.to_string());
        self.sizes.push(size);
        id
    }

    /// Id of an already-interned digest.
    pub fn lookup(&self, digest: &str) -> Option<LayerId> {
        self.by_digest.get(digest).copied()
    }

    /// Size of an interned layer.
    pub fn size(&self, id: LayerId) -> Bytes {
        self.sizes[id.0 as usize]
    }

    /// Digest of an interned layer.
    pub fn digest(&self, id: LayerId) -> &str {
        &self.digests[id.0 as usize]
    }

    /// Number of distinct layers seen.
    pub fn len(&self) -> usize {
        self.digests.len()
    }

    /// Has nothing been interned yet?
    pub fn is_empty(&self) -> bool {
        self.digests.is_empty()
    }

    /// Layer sizes as f32 MB, padded to `cap` — the dense vector handed to
    /// the XLA scoring artifact.
    pub fn sizes_mb_padded(&self, cap: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; cap.max(self.len())];
        for (i, s) in self.sizes.iter().enumerate() {
            v[i] = s.as_mb() as f32;
        }
        v.truncate(cap.max(self.len()));
        v
    }
}

/// A set of layers as a bitset over interned ids. Supports the three
/// operations the scheduler needs: union (node gains layers), intersection
/// size in bytes (Eq. 2), and difference size in bytes (Eq. 1).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LayerSet {
    words: Vec<u64>,
}

impl LayerSet {
    /// The empty set.
    pub fn new() -> LayerSet {
        LayerSet::default()
    }

    /// A set holding exactly `ids`.
    pub fn from_ids(ids: &[LayerId]) -> LayerSet {
        let mut s = LayerSet::new();
        for &id in ids {
            s.insert(id);
        }
        s
    }

    fn ensure(&mut self, word: usize) {
        if self.words.len() <= word {
            self.words.resize(word + 1, 0);
        }
    }

    /// Add a layer.
    pub fn insert(&mut self, id: LayerId) {
        let (w, b) = (id.0 as usize / 64, id.0 as usize % 64);
        self.ensure(w);
        self.words[w] |= 1 << b;
    }

    /// Remove a layer (no-op when absent).
    pub fn remove(&mut self, id: LayerId) {
        let (w, b) = (id.0 as usize / 64, id.0 as usize % 64);
        if w < self.words.len() {
            self.words[w] &= !(1 << b);
        }
    }

    /// Is `id` in the set?
    pub fn contains(&self, id: LayerId) -> bool {
        let (w, b) = (id.0 as usize / 64, id.0 as usize % 64);
        w < self.words.len() && self.words[w] & (1 << b) != 0
    }

    /// Number of layers in the set.
    pub fn len(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Is the set empty?
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// In-place union (node gains `other`'s layers).
    pub fn union_with(&mut self, other: &LayerSet) {
        self.ensure(other.words.len().saturating_sub(1));
        for (i, &w) in other.words.iter().enumerate() {
            self.words[i] |= w;
        }
    }

    /// Iterate members in ascending id order.
    pub fn iter(&self) -> impl Iterator<Item = LayerId> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    return None;
                }
                let b = bits.trailing_zeros();
                bits &= bits - 1;
                Some(LayerId((wi * 64) as u32 + b))
            })
        })
    }

    /// Total bytes of `self ∩ other` (Eq. 2: local hit size `D_c^n`).
    pub fn intersection_bytes(&self, other: &LayerSet, interner: &LayerInterner) -> Bytes {
        let mut total = Bytes::ZERO;
        let n = self.words.len().min(other.words.len());
        for wi in 0..n {
            let mut bits = self.words[wi] & other.words[wi];
            while bits != 0 {
                let b = bits.trailing_zeros();
                bits &= bits - 1;
                total += interner.size(LayerId((wi * 64) as u32 + b));
            }
        }
        total
    }

    /// Total bytes of `self \ other` (Eq. 1: download cost `C_c^n`).
    pub fn difference_bytes(&self, other: &LayerSet, interner: &LayerInterner) -> Bytes {
        let mut total = Bytes::ZERO;
        for wi in 0..self.words.len() {
            let o = other.words.get(wi).copied().unwrap_or(0);
            let mut bits = self.words[wi] & !o;
            while bits != 0 {
                let b = bits.trailing_zeros();
                bits &= bits - 1;
                total += interner.size(LayerId((wi * 64) as u32 + b));
            }
        }
        total
    }

    /// Layer ids in `self \ other` (the layers a node must pull).
    pub fn difference_ids(&self, other: &LayerSet) -> Vec<LayerId> {
        let mut ids = Vec::new();
        for wi in 0..self.words.len() {
            let o = other.words.get(wi).copied().unwrap_or(0);
            let mut bits = self.words[wi] & !o;
            while bits != 0 {
                let b = bits.trailing_zeros();
                bits &= bits - 1;
                ids.push(LayerId((wi * 64) as u32 + b));
            }
        }
        ids
    }

    /// Total bytes of all layers in the set.
    pub fn total_bytes(&self, interner: &LayerInterner) -> Bytes {
        self.iter().map(|id| interner.size(id)).sum()
    }

    /// Fill `out[layer_id] = 1.0` for members; `out` must be zeroed and at
    /// least `interner.len()` long. Used to build the XLA presence matrix.
    pub fn write_indicator(&self, out: &mut [f32]) {
        for id in self.iter() {
            if (id.0 as usize) < out.len() {
                out[id.0 as usize] = 1.0;
            }
        }
    }
}

impl FromIterator<LayerId> for LayerSet {
    fn from_iter<T: IntoIterator<Item = LayerId>>(iter: T) -> LayerSet {
        let mut s = LayerSet::new();
        for id in iter {
            s.insert(id);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn interner_with(sizes_mb: &[f64]) -> (LayerInterner, Vec<LayerId>) {
        let mut interner = LayerInterner::new();
        let ids = sizes_mb
            .iter()
            .enumerate()
            .map(|(i, &mb)| interner.intern(&format!("sha256:{i:04x}"), Bytes::from_mb(mb)))
            .collect();
        (interner, ids)
    }

    #[test]
    fn intern_dedups() {
        let mut interner = LayerInterner::new();
        let a = interner.intern("sha256:aa", Bytes::from_mb(5.0));
        let b = interner.intern("sha256:aa", Bytes::from_mb(5.0));
        assert_eq!(a, b);
        assert_eq!(interner.len(), 1);
        assert_eq!(interner.size(a), Bytes::from_mb(5.0));
        assert_eq!(interner.digest(a), "sha256:aa");
        assert_eq!(interner.lookup("sha256:aa"), Some(a));
        assert_eq!(interner.lookup("sha256:bb"), None);
    }

    #[test]
    fn set_basics() {
        let (_, ids) = interner_with(&[1.0, 2.0, 3.0]);
        let mut s = LayerSet::new();
        assert!(s.is_empty());
        s.insert(ids[0]);
        s.insert(ids[2]);
        assert!(s.contains(ids[0]));
        assert!(!s.contains(ids[1]));
        assert_eq!(s.len(), 2);
        s.remove(ids[0]);
        assert!(!s.contains(ids[0]));
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![ids[2]]);
    }

    #[test]
    fn set_works_across_word_boundaries() {
        let mut s = LayerSet::new();
        for i in [0u32, 63, 64, 65, 127, 128, 1000] {
            s.insert(LayerId(i));
        }
        assert_eq!(s.len(), 7);
        assert!(s.contains(LayerId(1000)));
        assert!(!s.contains(LayerId(999)));
        let collected: Vec<u32> = s.iter().map(|l| l.0).collect();
        assert_eq!(collected, vec![0, 63, 64, 65, 127, 128, 1000]);
    }

    #[test]
    fn intersection_and_difference_bytes() {
        let (interner, ids) = interner_with(&[10.0, 20.0, 30.0, 40.0]);
        let req = LayerSet::from_ids(&[ids[0], ids[1], ids[3]]); // 10+20+40
        let node = LayerSet::from_ids(&[ids[1], ids[2]]); // has 20, 30
        assert_eq!(req.intersection_bytes(&node, &interner), Bytes::from_mb(20.0));
        assert_eq!(req.difference_bytes(&node, &interner), Bytes::from_mb(50.0));
        assert_eq!(req.difference_ids(&node), vec![ids[0], ids[3]]);
        assert_eq!(req.total_bytes(&interner), Bytes::from_mb(70.0));
    }

    #[test]
    fn union_grows() {
        let (_, ids) = interner_with(&[1.0; 5]);
        let mut a = LayerSet::from_ids(&[ids[0]]);
        let b = LayerSet::from_ids(&[ids[3], ids[4]]);
        a.union_with(&b);
        assert_eq!(a.len(), 3);
        assert!(a.contains(ids[4]));
    }

    #[test]
    fn indicator_vector() {
        let (_, ids) = interner_with(&[1.0, 1.0, 1.0]);
        let s = LayerSet::from_ids(&[ids[0], ids[2]]);
        let mut out = vec![0.0f32; 4];
        s.write_indicator(&mut out);
        assert_eq!(out, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn empty_set_edge_cases() {
        let (interner, ids) = interner_with(&[7.0]);
        let empty = LayerSet::new();
        let full = LayerSet::from_ids(&[ids[0]]);
        assert_eq!(empty.intersection_bytes(&full, &interner), Bytes::ZERO);
        assert_eq!(full.difference_bytes(&empty, &interner), Bytes::from_mb(7.0));
        assert_eq!(empty.difference_bytes(&full, &interner), Bytes::ZERO);
    }

    #[test]
    fn sizes_mb_padded() {
        let (interner, _) = interner_with(&[1.5, 2.5]);
        let v = interner.sizes_mb_padded(4);
        assert_eq!(v, vec![1.5, 2.5, 0.0, 0.0]);
    }
}
