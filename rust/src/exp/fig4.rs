//! Figure 4 — "Download time at various bandwidths": total download time
//! for the 20-pod trace as the per-node bandwidth sweeps from edge-poor to
//! edge-rich. The paper reports LRScheduler reducing download time by ~39%
//! on average vs. the default scheduler, with the gap widening at low
//! bandwidth.

use super::common;
use super::report;
use crate::sim::SchedulerChoice;

/// The bandwidth sweep (MB/s), edge-poor to edge-rich.
pub const BANDWIDTHS_MBPS: [f64; 5] = [2.0, 4.0, 8.0, 16.0, 32.0];

/// The figure's data: one download-time series per scheduler.
#[derive(Debug, Clone)]
pub struct Fig4 {
    /// Swept bandwidths (MB/s).
    pub bandwidths_mbps: Vec<f64>,
    /// Per scheduler: total download seconds at each bandwidth.
    pub secs: Vec<(&'static str, Vec<f64>)>,
}

/// Regenerate the figure's data for a seeded workload.
pub fn run(seed: u64, n_pods: usize, n_nodes: usize) -> Fig4 {
    let trace = common::paper_trace(seed, n_pods);
    let mut secs: Vec<(&'static str, Vec<f64>)> = SchedulerChoice::all()
        .iter()
        .map(|c| (c.label(), Vec::new()))
        .collect();
    for &bw in &BANDWIDTHS_MBPS {
        for (i, rep) in common::run_all(n_nodes, &trace, |cfg| {
            cfg.bandwidth_mbps = Some(bw);
        })
        .into_iter()
        .enumerate()
        {
            secs[i].1.push(rep.total_download_secs());
        }
    }
    Fig4 { bandwidths_mbps: BANDWIDTHS_MBPS.to_vec(), secs }
}

impl Fig4 {
    /// Download-time series of one scheduler (panics when absent).
    pub fn series_for(&self, scheduler: &str) -> &[f64] {
        &self.secs.iter().find(|(s, _)| *s == scheduler).expect("series").1
    }

    /// Mean relative reduction of LRScheduler vs. Default across the sweep.
    pub fn lr_reduction_vs_default(&self) -> f64 {
        let def = self.series_for("Default");
        let lr = self.series_for("LRScheduler");
        def.iter()
            .zip(lr)
            .map(|(d, l)| if *d > 0.0 { 1.0 - l / d } else { 0.0 })
            .sum::<f64>()
            / def.len() as f64
    }

    /// Render the figure as aligned text series.
    pub fn print(&self) -> String {
        let mut out = String::from("Fig. 4 — download time (s) vs bandwidth (MB/s)\n");
        let lines: Vec<(String, Vec<f64>)> = std::iter::once((
            "bandwidth".to_string(),
            self.bandwidths_mbps.clone(),
        ))
        .chain(self.secs.iter().map(|(s, v)| (s.to_string(), v.clone())))
        .collect();
        out.push_str(&report::series("", &lines, 1));
        out.push_str(&format!(
            "LRScheduler download-time reduction vs Default: {:.0}%  (paper: 39%)\n",
            self.lr_reduction_vs_default() * 100.0
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_holds() {
        let fig = run(42, 20, 4);
        let def = fig.series_for("Default").to_vec();
        let lr = fig.series_for("LRScheduler").to_vec();
        // LR at-or-below Default at every bandwidth; strictly below overall.
        for (d, l) in def.iter().zip(&lr) {
            assert!(l <= &(d * 1.001), "lr {l} > default {d}");
        }
        assert!(fig.lr_reduction_vs_default() > 0.1);
        // Both series shrink as bandwidth grows (T = C/b).
        assert!(def.windows(2).all(|w| w[1] < w[0]));
        assert!(lr.windows(2).all(|w| w[1] < w[0]));
        // Absolute advantage is biggest at the lowest bandwidth.
        let gap_low = def[0] - lr[0];
        let gap_high = def[4] - lr[4];
        assert!(gap_low > gap_high, "low-bw gap {gap_low} vs {gap_high}");
    }
}
