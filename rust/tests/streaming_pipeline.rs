//! Differential tests for the streaming arrival pipeline (PR 5): the
//! pull-based constant-memory path (`TraceReplay` → `TraceSource` →
//! `Simulation::run_source`) must produce **byte-identical**
//! `SimReport`/`EventLog` fingerprints to the buffered path
//! (`trace::load` → `Trace::arrivals` → `Simulation::run_arrivals`) on
//! the bundled fixtures — for both formats, both error modes, shard
//! counts {1, 4}, gzipped and plain inputs, and under churn. Plus
//! end-to-end coverage for the bounded reorder buffer and the
//! `--trace-limit` ingestion short-circuit.

use lrsched::exp::common;
use lrsched::sim::{
    trace, ChurnConfig, ErrorMode, IngestPath, SimConfig, Simulation, TraceFormat, TraceOptions,
    TraceReplay,
};
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn sim_cfg(shards: usize, churn: Option<ChurnConfig>) -> SimConfig {
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(0.3); // timed mode; offsets are explicit
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 10;
    cfg.shards = shards;
    cfg.churn = churn;
    cfg
}

/// The buffered reference: whole trace materialized, arrivals replayed
/// through `run_arrivals`.
fn buffered_fingerprint(
    path: &Path,
    opts: &TraceOptions,
    shards: usize,
    churn: Option<ChurnConfig>,
) -> String {
    let t = trace::load(path, opts).expect("fixture parses");
    let registry = t.synthesize_registry();
    let arrivals = t.arrivals();
    let mut sim = Simulation::new(common::scale_nodes(8), registry, sim_cfg(shards, churn));
    let report = sim.run_arrivals(arrivals);
    sim.state.check_invariants().expect("cluster invariants");
    assert!(report.accounting_balanced());
    format!("{}\n{}", report.render(), sim.events.render())
}

/// The streaming path under test: scan pass + pull-based source through
/// `run_source`, one arrival in memory at a time.
fn streaming_fingerprint(
    path: &Path,
    opts: &TraceOptions,
    shards: usize,
    churn: Option<ChurnConfig>,
) -> String {
    let replay = TraceReplay::open(path, opts).expect("fixture parses");
    let registry = replay.synthesize_registry();
    let expected = replay.stats.events;
    let mut sim = Simulation::new(common::scale_nodes(8), registry, sim_cfg(shards, churn));
    let report = sim.run_source(Box::new(replay.into_source()));
    sim.state.check_invariants().expect("cluster invariants");
    assert_eq!(report.submitted, expected, "streaming source ended early");
    assert!(report.accounting_balanced());
    format!("{}\n{}", report.render(), sim.events.render())
}

#[test]
fn streaming_matches_buffered_on_fixtures() {
    // Both formats × both error modes × shards {1, 4}: the streaming
    // pipeline must be byte-identical to the buffered path everywhere.
    for (name, format) in [
        ("alibaba_mini.csv", TraceFormat::Alibaba),
        ("azure_mini.csv", TraceFormat::Azure),
    ] {
        for mode in [ErrorMode::Lenient, ErrorMode::Strict] {
            let opts = TraceOptions { format, mode, ..Default::default() };
            let path = fixture(name);
            for shards in [1usize, 4] {
                let buffered = buffered_fingerprint(&path, &opts, shards, None);
                let streaming = streaming_fingerprint(&path, &opts, shards, None);
                assert_eq!(
                    buffered, streaming,
                    "{name} {mode:?} shards={shards}: streaming diverged from buffered"
                );
            }
        }
    }
}

#[test]
fn streaming_matches_buffered_under_churn() {
    let churn = || {
        Some(ChurnConfig {
            seed: 5,
            horizon_secs: 600.0,
            joins: 2,
            drains: 1,
            crash_fraction: 0.25,
            outages: 1,
            outage_secs: 30.0,
            ..Default::default()
        })
    };
    let opts = TraceOptions::default();
    let path = fixture("alibaba_mini.csv");
    for shards in [1usize, 4] {
        let buffered = buffered_fingerprint(&path, &opts, shards, churn());
        let streaming = streaming_fingerprint(&path, &opts, shards, churn());
        assert_eq!(buffered, streaming, "churn shards={shards}: streaming diverged");
    }
}

#[test]
fn gzipped_streaming_replay_matches_plain() {
    // .csv.gz streams through the bounded-memory GzDecoder; the whole
    // replay must be byte-identical to the plain file.
    let opts = TraceOptions::default();
    let plain = streaming_fingerprint(&fixture("alibaba_mini.csv"), &opts, 1, None);
    let gz = streaming_fingerprint(&fixture("alibaba_mini.csv.gz"), &opts, 1, None);
    assert_eq!(plain, gz);
}

/// Write a deterministic out-of-order Alibaba-dialect trace: every
/// quadruple of rows reversed (max displacement 3), over 10 recurring
/// apps.
fn write_shuffled_trace(path: &Path) {
    let mut rows: Vec<String> = (0..120)
        .map(|i| {
            format!(
                "task_{},1,j_{i},A,Terminated,{},{},50,0.5",
                i % 10,
                1000 + i,
                1030 + i
            )
        })
        .collect();
    for block in rows.chunks_mut(4) {
        block.reverse();
    }
    std::fs::write(path, rows.join("\n")).expect("write shuffled trace");
}

#[test]
fn bounded_reorder_buffer_replays_identically() {
    let path = std::env::temp_dir()
        .join(format!("lrsched-shuffled-{}.csv", std::process::id()));
    write_shuffled_trace(&path);

    // Reference: effectively unbounded buffer.
    let big = TraceOptions { reorder_cap: 100_000, ..Default::default() };
    let reference = streaming_fingerprint(&path, &big, 1, None);

    // Bounded buffer big enough for the displacement: byte-identical.
    let bounded = TraceOptions { reorder_cap: 8, ..Default::default() };
    let replay = TraceReplay::open(&path, &bounded).expect("parses");
    assert!(replay.stats.resorted);
    assert!(!replay.stats.full_resort, "displacement 3 must fit a cap of 8");
    assert_eq!(replay.stats.reorder_depth, 3, "reversed quadruples displace by 3");
    assert_eq!(
        replay.stats.ingest_path,
        IngestPath::BoundedReorder,
        "measured disorder within the cap must select the bounded heap"
    );
    drop(replay);
    assert_eq!(streaming_fingerprint(&path, &bounded, 1, None), reference);

    // Cap too small for displacement 3: the scan pass must detect the
    // overflow and fall back to the whole-trace sort — still
    // byte-identical.
    let tiny = TraceOptions { reorder_cap: 1, ..Default::default() };
    let replay = TraceReplay::open(&path, &tiny).expect("parses");
    assert!(replay.stats.full_resort, "cap 1 cannot hold displacement 3");
    assert_eq!(replay.stats.ingest_path, IngestPath::FullResort);
    drop(replay);
    assert_eq!(streaming_fingerprint(&path, &tiny, 1, None), reference);

    // And the buffered path agrees with all of them.
    assert_eq!(buffered_fingerprint(&path, &bounded, 1, None), reference);

    let _ = std::fs::remove_file(&path);
}

#[test]
fn trace_limit_short_circuits_ingestion() {
    let opts = TraceOptions { limit: Some(10), ..Default::default() };
    let replay = TraceReplay::open(&fixture("alibaba_mini.csv"), &opts).expect("parses");
    assert_eq!(replay.stats.events, 10);
    assert!(replay.stats.limit_hit, "the cut must be visible in stats");
    // Short-circuit: the full fixture has 36 data rows; only the prefix
    // needed for 10 events was read.
    assert!(
        replay.stats.rows < 36,
        "ingestion read {} rows; it must stop at the limit",
        replay.stats.rows
    );
    // The truncated replay still runs and balances.
    let registry = replay.synthesize_registry();
    let mut sim = Simulation::new(common::scale_nodes(4), registry, sim_cfg(1, None));
    let report = sim.run_source(Box::new(replay.into_source()));
    assert_eq!(report.submitted, 10);
    assert!(report.accounting_balanced());
    // And it matches the buffered limit semantics byte-for-byte.
    let buffered = buffered_fingerprint(&fixture("alibaba_mini.csv"), &opts, 1, None);
    let streaming = streaming_fingerprint(&fixture("alibaba_mini.csv"), &opts, 1, None);
    assert_eq!(buffered, streaming);
}

#[test]
fn uppercase_gz_extension_still_decompresses() {
    // Extension handling is case-insensitive on both the reject list and
    // the gzip route: a `.CSV.GZ` trace must inflate, not be fed as raw
    // compressed bytes to the CSV parser.
    let gz = std::fs::read(fixture("alibaba_mini.csv.gz")).expect("fixture exists");
    let path = std::env::temp_dir()
        .join(format!("LRSCHED-UPPER-{}.CSV.GZ", std::process::id()));
    std::fs::write(&path, gz).expect("write uppercase fixture");
    let replay = TraceReplay::open(&path, &TraceOptions::default())
        .expect("uppercase .GZ must decompress");
    assert_eq!(replay.stats.events, 53);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn borg_dialect_replays_end_to_end() {
    // A small Borg task_events window: SUBMIT rows become service pods,
    // lifecycle rows are filtered, and the replay balances. `--trace-limit`
    // keeps it bounded despite services never terminating.
    let path = std::env::temp_dir().join(format!("lrsched-borg-{}.csv", std::process::id()));
    let mut rows = String::new();
    for i in 0..30 {
        // SUBMIT (type 0) + SCHEDULE (type 1) per task, jobs recur.
        rows.push_str(&format!(
            "{},,job{},{i},,0,u1,2,9,0.05,0.05,0.001,0\n",
            i * 1_000_000,
            i % 5
        ));
        rows.push_str(&format!(
            "{},,job{},{i},m1,1,u1,2,9,0.05,0.05,0.001,0\n",
            i * 1_000_000 + 500_000,
            i % 5
        ));
    }
    std::fs::write(&path, rows).expect("write borg trace");

    let opts = TraceOptions { format: TraceFormat::Borg, ..Default::default() };
    let replay = TraceReplay::open(&path, &opts).expect("borg trace parses");
    assert_eq!(replay.stats.events, 30);
    assert_eq!(replay.stats.filtered, 30, "SCHEDULE rows are filtered, not errors");
    assert_eq!(replay.stats.apps, 5);
    let buffered = buffered_fingerprint(&path, &opts, 1, None);
    let streaming = streaming_fingerprint(&path, &opts, 1, None);
    assert_eq!(buffered, streaming, "borg: streaming diverged from buffered");
    let _ = std::fs::remove_file(&path);
}
