//! Cluster state store — the etcd analog. Owns the node table, the pod
//! table, pod→node bindings, and the shared [`LayerInterner`], and exposes
//! the mutation API the API server / kubelets drive: bind, install image,
//! evict, release.

use super::node::{Node, NodeId, NodeStatus};
use super::pod::{Pod, PodId};
use crate::registry::{ImageMetadata, ImageRef, LayerId, LayerInterner, LayerSet};
use crate::util::units::Bytes;
use std::collections::BTreeMap;

/// Errors from state mutations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StateError {
    /// Referenced node id does not exist.
    UnknownNode(u32),
    /// Referenced pod id does not exist.
    UnknownPod(u64),
    /// Bind attempted on an already-bound pod.
    AlreadyBound(u64),
    /// Image install exceeded the node's disk.
    DiskFull {
        /// The full node.
        node: u32,
        /// Bytes the install needed.
        need: Bytes,
        /// Bytes actually free.
        free: Bytes,
    },
}

impl std::fmt::Display for StateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StateError::UnknownNode(n) => write!(f, "unknown node {n}"),
            StateError::UnknownPod(p) => write!(f, "unknown pod {p}"),
            StateError::AlreadyBound(p) => write!(f, "pod {p} already bound"),
            StateError::DiskFull { node, need, free } => {
                write!(f, "node {node} disk full: need {need}, free {free}")
            }
        }
    }
}

impl std::error::Error for StateError {}

/// The cluster state.
#[derive(Debug, Clone, Default)]
pub struct ClusterState {
    nodes: Vec<Node>,
    pods: BTreeMap<PodId, Pod>,
    bindings: BTreeMap<PodId, NodeId>,
    /// Shared content-addressed layer interner (digest ↔ dense id).
    pub interner: LayerInterner,
}

/// Install an image directly on one node: adds missing layers, charges
/// disk (Eq. 6 capacity check), records the image. Returns bytes added.
///
/// This is the node-level body of [`ClusterState::install_image`], split
/// out so the sharded engine's event lanes — which hold disjoint
/// `&mut [Node]` slices rather than the whole state — run the exact same
/// mutation (`docs/ARCHITECTURE.md`, "Sharded event lanes").
pub fn install_image_on(
    node: &mut Node,
    interner: &LayerInterner,
    image: &ImageRef,
    layers: &LayerSet,
) -> Result<Bytes, StateError> {
    let added = layers.difference_bytes(&node.layers, interner);
    let free = node.disk.saturating_sub(node.disk_used);
    if added > free {
        return Err(StateError::DiskFull { node: node.id.0, need: added, free });
    }
    // Bump on any membership change (layer sizes can be zero, so the
    // byte delta alone must not gate the version).
    let members_before = node.layers.len();
    node.layers.union_with(layers);
    if node.layers.len() != members_before {
        node.layers_version += 1;
    }
    node.disk_used += added;
    if !node.has_image(image) {
        node.images.push(image.clone());
    }
    Ok(added)
}

/// Evict specific layers directly from one node (disk-pressure GC) — the
/// node-level body of [`ClusterState::evict_layers`], shared with the
/// sharded engine's event lanes. Returns bytes freed.
pub fn evict_layers_on(node: &mut Node, interner: &LayerInterner, layers: &[LayerId]) -> Bytes {
    let mut freed = Bytes::ZERO;
    let mut removed_any = false;
    for &l in layers {
        if node.layers.contains(l) {
            node.layers.remove(l);
            node.cache_meta.remove(&l);
            removed_any = true;
            freed += interner.size(l);
        }
    }
    if removed_any {
        node.layers_version += 1;
    }
    node.disk_used = node.disk_used.saturating_sub(freed);
    freed
}

/// Warm individual `layers` onto one node ahead of any pull (the
/// prefetch-on-intent cache policy): installs the ones that are absent
/// *and* fit the remaining disk, charges disk, bumps the layer version,
/// and stamps the LRU metadata at `now`. Unlike [`install_image_on`]
/// there is no image record — prefetched layers not later claimed by an
/// installed image are *orphans*, reclaimable by the prefetch policy's
/// GC sweep (`sim/kubelet.rs`). Returns (bytes added, layers added).
pub fn prefetch_layers_on(
    node: &mut Node,
    interner: &LayerInterner,
    layers: &[LayerId],
    now: f64,
) -> (Bytes, usize) {
    let mut added = Bytes::ZERO;
    let mut count = 0usize;
    for &l in layers {
        if node.layers.contains(l) {
            continue;
        }
        let size = interner.size(l);
        if size > node.disk_free() {
            continue;
        }
        node.layers.insert(l);
        node.disk_used += size;
        node.touch_layer_install(l, now);
        added += size;
        count += 1;
    }
    if count > 0 {
        node.layers_version += 1;
    }
    (added, count)
}

impl ClusterState {
    /// An empty cluster.
    pub fn new() -> ClusterState {
        ClusterState::default()
    }

    // --- nodes ------------------------------------------------------------

    /// Register a node (ids must be dense and in order).
    pub fn add_node(&mut self, node: Node) -> NodeId {
        debug_assert_eq!(node.id.0 as usize, self.nodes.len(), "node ids must be dense");
        let id = node.id;
        self.nodes.push(node);
        id
    }

    /// Node by id (panics on unknown ids — ids are dense).
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    /// Mutable node access (prefer the mutation API below).
    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0 as usize]
    }

    /// All nodes, dense by id.
    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    /// Total nodes ever registered (including Down ones).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// The id the next joining node must use (ids are dense).
    pub fn next_node_id(&self) -> NodeId {
        NodeId(self.nodes.len() as u32)
    }

    /// Nodes currently accepting new pods.
    pub fn schedulable_node_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_schedulable()).count()
    }

    // --- churn (node lifecycle) --------------------------------------------

    /// Cordon a node: running pods finish, no new bindings (kubectl drain).
    pub fn drain_node(&mut self, id: NodeId) {
        self.nodes[id.0 as usize].status = NodeStatus::Draining;
    }

    /// Crash a node: its pods lose their bindings (the caller resubmits
    /// them), and its image/layer inventory is gone — a replacement node
    /// would start cold, per edge-volatility models (EdgePier). Returns the
    /// pods that were bound there, in binding order.
    pub fn crash_node(&mut self, id: NodeId) -> Vec<PodId> {
        let lost = self.nodes[id.0 as usize].pods.clone();
        for &pid in &lost {
            let _ = self.unbind(pid);
        }
        let node = &mut self.nodes[id.0 as usize];
        node.status = NodeStatus::Down;
        node.layers = LayerSet::new();
        node.layers_version += 1;
        node.images.clear();
        node.disk_used = Bytes::ZERO;
        node.cache_meta.clear();
        lost
    }

    // --- pods ---------------------------------------------------------------

    /// Register a pod with the API server.
    pub fn submit_pod(&mut self, pod: Pod) -> PodId {
        let id = pod.id;
        self.pods.insert(id, pod);
        id
    }

    /// Pod by id, if known.
    pub fn pod(&self, id: PodId) -> Option<&Pod> {
        self.pods.get(&id)
    }

    /// Every submitted pod.
    pub fn pods(&self) -> impl Iterator<Item = &Pod> {
        self.pods.values()
    }

    /// Node a pod is bound to, if any.
    pub fn binding(&self, pod: PodId) -> Option<NodeId> {
        self.bindings.get(&pod).copied()
    }

    /// The full pod → node binding table.
    pub fn bindings(&self) -> &BTreeMap<PodId, NodeId> {
        &self.bindings
    }

    /// Pods bound to `node` (for inter-pod affinity / topology spread).
    /// Reads the node's own pod list — O(pods on node), not O(bindings) —
    /// because the scoring plugins call this per node per cycle (§Perf).
    pub fn pods_on(&self, node: NodeId) -> impl Iterator<Item = &Pod> {
        self.nodes[node.0 as usize]
            .pods
            .iter()
            .filter_map(|p| self.pods.get(p))
    }

    /// Bind a pod to a node: reserves the pod's requested resources.
    /// Enforces Eq. (8): a pod binds to exactly one node.
    pub fn bind(&mut self, pod_id: PodId, node_id: NodeId) -> Result<(), StateError> {
        if self.bindings.contains_key(&pod_id) {
            return Err(StateError::AlreadyBound(pod_id.0));
        }
        let requests = self
            .pods
            .get(&pod_id)
            .ok_or(StateError::UnknownPod(pod_id.0))?
            .requests;
        if node_id.0 as usize >= self.nodes.len() {
            return Err(StateError::UnknownNode(node_id.0));
        }
        self.nodes[node_id.0 as usize].assign(pod_id, requests);
        self.bindings.insert(pod_id, node_id);
        Ok(())
    }

    /// Remove a pod: releases its resources (layers stay cached — image
    /// retention is kubelet GC's job, as on real nodes).
    pub fn unbind(&mut self, pod_id: PodId) -> Result<(), StateError> {
        let node_id = self
            .bindings
            .remove(&pod_id)
            .ok_or(StateError::UnknownPod(pod_id.0))?;
        let requests = self.pods[&pod_id].requests;
        self.nodes[node_id.0 as usize].release(pod_id, requests);
        Ok(())
    }

    /// Remove only the binding-table entry for `pod`, returning its node —
    /// the first half of [`ClusterState::unbind`]. The sharded engine's
    /// coordinator calls this while routing a termination; the owning lane
    /// then applies the node-side [`Node::release`] in event order. Until
    /// both halves run, the node's pod list and the binding table disagree
    /// — callers must complete the pair before anything validates
    /// invariants.
    pub fn take_binding(&mut self, pod: PodId) -> Option<NodeId> {
        self.bindings.remove(&pod)
    }

    /// Split the state into the disjoint borrows a parallel lane window
    /// needs: the dense node table (mutable — partitioned into per-lane
    /// slices by the caller), plus shared views of the pod table and the
    /// layer interner. Bindings stay with the coordinator
    /// ([`ClusterState::take_binding`]).
    pub fn lane_split(&mut self) -> (&mut [Node], &BTreeMap<PodId, Pod>, &LayerInterner) {
        (&mut self.nodes, &self.pods, &self.interner)
    }

    // --- image/layer inventory ---------------------------------------------

    /// Intern an image's layers, returning (ids, layer set).
    pub fn intern_image(&mut self, meta: &ImageMetadata) -> (Vec<LayerId>, LayerSet) {
        let ids: Vec<LayerId> = meta
            .layers
            .iter()
            .map(|l| self.interner.intern(&l.digest, l.size))
            .collect();
        let set = LayerSet::from_ids(&ids);
        (ids, set)
    }

    /// Layers of `required` missing on `node`, i.e. L_c \ L_n(t).
    pub fn missing_layers(&self, node: NodeId, required: &LayerSet) -> Vec<LayerId> {
        required.difference_ids(&self.nodes[node.0 as usize].layers)
    }

    /// Bytes the node must download for `required` (Eq. 1).
    pub fn download_cost(&self, node: NodeId, required: &LayerSet) -> Bytes {
        required.difference_bytes(&self.nodes[node.0 as usize].layers, &self.interner)
    }

    /// Bytes of `required` already local (Eq. 2).
    pub fn local_bytes(&self, node: NodeId, required: &LayerSet) -> Bytes {
        required.intersection_bytes(&self.nodes[node.0 as usize].layers, &self.interner)
    }

    /// Install an image on a node: adds missing layers, charges disk
    /// (Eq. 6 capacity check), records the image. Returns bytes added.
    /// (Delegates to [`install_image_on`], the node-level form the sharded
    /// event lanes use directly.)
    pub fn install_image(
        &mut self,
        node_id: NodeId,
        image: &ImageRef,
        layers: &LayerSet,
    ) -> Result<Bytes, StateError> {
        install_image_on(&mut self.nodes[node_id.0 as usize], &self.interner, image, layers)
    }

    /// Evict specific layers from a node (disk-pressure GC).
    /// Layers shared with still-present images should not be passed here;
    /// the caller (kubelet GC) decides the victim set. Returns bytes freed.
    /// (Delegates to [`evict_layers_on`], the node-level form the sharded
    /// event lanes use directly.)
    pub fn evict_layers(&mut self, node_id: NodeId, layers: &[LayerId]) -> Bytes {
        evict_layers_on(&mut self.nodes[node_id.0 as usize], &self.interner, layers)
    }

    /// Warm individual layers onto a node ahead of any pull (prefetch-on-
    /// intent cache policy). Returns (bytes added, layers added); see
    /// [`prefetch_layers_on`].
    pub fn prefetch_layers(&mut self, node_id: NodeId, layers: &[LayerId], now: f64) -> (Bytes, usize) {
        prefetch_layers_on(&mut self.nodes[node_id.0 as usize], &self.interner, layers, now)
    }

    /// Drop an image record from a node (its unique layers should be passed
    /// to [`ClusterState::evict_layers`] separately).
    pub fn remove_image(&mut self, node_id: NodeId, image: &ImageRef) {
        self.nodes[node_id.0 as usize].images.retain(|i| i != image);
    }

    // --- invariants (exercised by property tests) ---------------------------

    /// Check Eq. (6)/(7)/(8) style invariants; returns a violation message.
    pub fn check_invariants(&self) -> Result<(), String> {
        // Each bound pod maps to a valid node and appears in that node's list.
        for (&pod, &node) in &self.bindings {
            if node.0 as usize >= self.nodes.len() {
                return Err(format!("pod {} bound to unknown node {}", pod.0, node.0));
            }
            if !self.nodes[node.0 as usize].pods.contains(&pod) {
                return Err(format!("pod {} missing from node {} pod list", pod.0, node.0));
            }
        }
        for node in &self.nodes {
            // A crashed node holds nothing.
            if node.status == NodeStatus::Down
                && !(node.pods.is_empty() && node.layers.is_empty())
            {
                return Err(format!("down node {} still holds pods/layers", node.name));
            }
            // Disk accounting matches the layer set.
            let computed = node.layers.total_bytes(&self.interner);
            if computed != node.disk_used {
                return Err(format!(
                    "node {}: disk_used {} != layer bytes {}",
                    node.name, node.disk_used, computed
                ));
            }
            if node.disk_used > node.disk {
                return Err(format!("node {}: disk overcommitted", node.name));
            }
            // Used resources equal the sum of bound pod requests.
            let mut sum = crate::cluster::resources::Resources::ZERO;
            for &p in &node.pods {
                sum += self.pods[&p].requests;
            }
            if sum != node.used {
                return Err(format!("node {}: used mismatch", node.name));
            }
            // A pod appears on at most one node (Eq. 8).
            for &p in &node.pods {
                if self.bindings.get(&p) != Some(&node.id) {
                    return Err(format!("pod {} on node {} without binding", p.0, node.name));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::PodBuilder;
    use crate::cluster::resources::Resources;
    use crate::registry::hub;
    use crate::util::units::Bandwidth;

    fn cluster() -> ClusterState {
        let mut s = ClusterState::new();
        for i in 0..3 {
            s.add_node(Node::new(
                NodeId(i),
                &format!("worker{}", i + 1),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(20.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        s
    }

    #[test]
    fn bind_reserves_resources() {
        let mut s = cluster();
        let mut b = PodBuilder::new();
        let pod = b.build("redis:7.2", Resources::cores_gb(1.0, 1.0));
        let pid = s.submit_pod(pod);
        s.bind(pid, NodeId(1)).unwrap();
        assert_eq!(s.binding(pid), Some(NodeId(1)));
        assert_eq!(s.node(NodeId(1)).used, Resources::cores_gb(1.0, 1.0));
        assert_eq!(s.pods_on(NodeId(1)).count(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn double_bind_rejected() {
        let mut s = cluster();
        let mut b = PodBuilder::new();
        let pid = s.submit_pod(b.build("redis:7.2", Resources::ZERO));
        s.bind(pid, NodeId(0)).unwrap();
        assert_eq!(s.bind(pid, NodeId(1)), Err(StateError::AlreadyBound(pid.0)));
    }

    #[test]
    fn unbind_releases() {
        let mut s = cluster();
        let mut b = PodBuilder::new();
        let pid = s.submit_pod(b.build("redis:7.2", Resources::cores_gb(2.0, 2.0)));
        s.bind(pid, NodeId(0)).unwrap();
        s.unbind(pid).unwrap();
        assert_eq!(s.node(NodeId(0)).used, Resources::ZERO);
        assert_eq!(s.binding(pid), None);
        s.check_invariants().unwrap();
    }

    #[test]
    fn install_image_charges_disk_once() {
        let mut s = cluster();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let (_, layers) = s.intern_image(wp);
        let added1 = s.install_image(NodeId(0), &wp.image_ref(), &layers).unwrap();
        assert_eq!(added1, wp.total_size);
        // Re-install: nothing new to download.
        let added2 = s.install_image(NodeId(0), &wp.image_ref(), &layers).unwrap();
        assert_eq!(added2, Bytes::ZERO);
        assert_eq!(s.node(NodeId(0)).images.len(), 1);
        s.check_invariants().unwrap();
    }

    #[test]
    fn layer_sharing_reduces_cost() {
        let mut s = cluster();
        let corpus = hub::corpus();
        let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let httpd = corpus.iter().find(|m| m.name == "httpd").unwrap();
        let (_, wp_layers) = s.intern_image(wp);
        let (_, httpd_layers) = s.intern_image(httpd);
        s.install_image(NodeId(0), &wp.image_ref(), &wp_layers).unwrap();
        // httpd shares debian+ca-certs+apache with wordpress.
        let cost_warm = s.download_cost(NodeId(0), &httpd_layers);
        let cost_cold = s.download_cost(NodeId(1), &httpd_layers);
        assert!(cost_warm < cost_cold);
        assert_eq!(cost_cold, httpd.total_size);
        let local = s.local_bytes(NodeId(0), &httpd_layers);
        assert_eq!(local + cost_warm, httpd.total_size);
        s.check_invariants().unwrap();
    }

    #[test]
    fn disk_full_rejected() {
        let mut s = ClusterState::new();
        s.add_node(Node::new(
            NodeId(0),
            "tiny",
            Resources::cores_gb(1.0, 1.0),
            Bytes::from_mb(100.0),
            Bandwidth::from_mbps(10.0),
        ));
        let corpus = hub::corpus();
        let gcc = corpus.iter().find(|m| m.name == "gcc").unwrap();
        let (_, layers) = s.intern_image(gcc);
        let err = s.install_image(NodeId(0), &gcc.image_ref(), &layers).unwrap_err();
        assert!(matches!(err, StateError::DiskFull { .. }));
        s.check_invariants().unwrap();
    }

    #[test]
    fn evict_frees_disk() {
        let mut s = cluster();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (ids, layers) = s.intern_image(redis);
        s.install_image(NodeId(0), &redis.image_ref(), &layers).unwrap();
        let freed = s.evict_layers(NodeId(0), &ids);
        assert_eq!(freed, redis.total_size);
        assert_eq!(s.node(NodeId(0)).disk_used, Bytes::ZERO);
        s.remove_image(NodeId(0), &redis.image_ref());
        assert!(s.node(NodeId(0)).images.is_empty());
        s.check_invariants().unwrap();
    }

    #[test]
    fn crash_unbinds_pods_and_wipes_inventory() {
        let mut s = cluster();
        let corpus = hub::corpus();
        let redis = corpus.iter().find(|m| m.name == "redis" && m.tag == "7.2").unwrap();
        let (_, layers) = s.intern_image(redis);
        s.install_image(NodeId(1), &redis.image_ref(), &layers).unwrap();
        let v0 = s.node(NodeId(1)).layers_version;
        let mut b = PodBuilder::new();
        let p1 = s.submit_pod(b.build("redis:7.2", Resources::cores_gb(1.0, 1.0)));
        let p2 = s.submit_pod(b.build("nginx:1.25", Resources::cores_gb(0.5, 0.5)));
        s.bind(p1, NodeId(1)).unwrap();
        s.bind(p2, NodeId(1)).unwrap();

        let lost = s.crash_node(NodeId(1));
        assert_eq!(lost, vec![p1, p2], "lost pods surface in binding order");
        let n = s.node(NodeId(1));
        assert_eq!(n.status, super::NodeStatus::Down);
        assert!(n.pods.is_empty());
        assert_eq!(n.used, Resources::ZERO);
        assert_eq!(n.disk_used, Bytes::ZERO);
        assert_eq!(n.layers.len(), 0);
        assert!(n.layers_version > v0, "arena dirty-row path must see the wipe");
        assert_eq!(s.binding(p1), None);
        // The pods themselves survive for resubmission.
        assert!(s.pod(p1).is_some() && s.pod(p2).is_some());
        s.check_invariants().unwrap();
    }

    #[test]
    fn drain_marks_node_unschedulable_but_up() {
        let mut s = cluster();
        let mut b = PodBuilder::new();
        let pid = s.submit_pod(b.build("redis:7.2", Resources::cores_gb(1.0, 1.0)));
        s.bind(pid, NodeId(0)).unwrap();
        s.drain_node(NodeId(0));
        let n = s.node(NodeId(0));
        assert!(!n.is_schedulable() && n.is_up());
        assert_eq!(n.pods, vec![pid], "running pods keep running through a drain");
        s.check_invariants().unwrap();
    }

    #[test]
    fn joined_node_gets_next_dense_id() {
        let mut s = cluster();
        let id = s.next_node_id();
        assert_eq!(id, NodeId(3));
        s.add_node(Node::new(
            id,
            "join1",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        ));
        assert_eq!(s.node_count(), 4);
        assert_eq!(s.schedulable_node_count(), 4);
        assert_eq!(s.node(id).layers.len(), 0, "joined nodes start cold");
    }

    #[test]
    fn missing_layers_listed() {
        let mut s = cluster();
        let corpus = hub::corpus();
        let nginx = corpus.iter().find(|m| m.name == "nginx").unwrap();
        let (ids, layers) = s.intern_image(nginx);
        assert_eq!(s.missing_layers(NodeId(0), &layers).len(), ids.len());
        s.install_image(NodeId(0), &nginx.image_ref(), &layers).unwrap();
        assert!(s.missing_layers(NodeId(0), &layers).is_empty());
    }
}
