//! The docs drift gate: the operator-facing books must keep up with the
//! CLI. Every flag the `scale`, `serve`, `gen-trace`, and `lint`
//! subcommands accept has to appear (as `--<name>`) in `docs/SCALE.md`
//! or `docs/SERVE.md`, and every relative markdown link anywhere under
//! `docs/` has to resolve to a real file — so a renamed flag or a moved
//! document fails `cargo test` instead of rotting silently. The specs
//! live in [`lrsched::cli::specs`], the single source both `main.rs` and
//! this gate read.

use lrsched::cli::specs;
use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // cargo test runs with cwd = rust/; the docs live beside it.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn read_doc(name: &str) -> String {
    let path = repo_root().join("docs").join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()))
}

/// Extract every inline markdown link target — the `path` in `](path)` —
/// from `text`.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = text;
    while let Some(i) = rest.find("](") {
        rest = &rest[i + 2..];
        if let Some(j) = rest.find(')') {
            out.push(rest[..j].trim().to_string());
            rest = &rest[j + 1..];
        } else {
            break;
        }
    }
    out
}

#[test]
fn repo_docs_are_complete() {
    // --- 1. every CLI flag is documented --------------------------------
    let books = [read_doc("SCALE.md"), read_doc("SERVE.md")].join("\n");
    let mut missing = Vec::new();
    for (cmd, spec) in [
        ("scale", specs::scale()),
        ("serve", specs::serve()),
        ("gen-trace", specs::gen_trace()),
        ("lint", specs::lint()),
    ] {
        for opt in spec {
            let flag = format!("--{}", opt.name);
            if !books.contains(&flag) {
                missing.push(format!("{cmd} {flag}"));
            }
        }
    }
    assert!(
        missing.is_empty(),
        "CLI flags missing from docs/SCALE.md and docs/SERVE.md (document them \
         or the operator's books drift): {missing:?}"
    );

    // --- 2. every relative doc link resolves ----------------------------
    let docs_dir = repo_root().join("docs");
    let mut broken = Vec::new();
    for entry in fs::read_dir(&docs_dir).expect("docs/ exists") {
        let path = entry.expect("readable dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("md") {
            continue;
        }
        let text = fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()));
        for target in link_targets(&text) {
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                || target.starts_with('#')
                || target.is_empty()
            {
                continue;
            }
            // Drop any fragment; resolve relative to the linking file.
            let file_part = target.split('#').next().unwrap_or(&target);
            let resolved = path.parent().unwrap_or(Path::new(".")).join(file_part);
            if !resolved.exists() {
                broken.push(format!(
                    "{} -> {target} (resolved {})",
                    path.file_name().and_then(|n| n.to_str()).unwrap_or("?"),
                    resolved.display()
                ));
            }
        }
    }
    assert!(broken.is_empty(), "broken relative links under docs/: {broken:?}");
}
