//! The four determinism-contract rule passes. Each is a token-sequence
//! matcher over one file's code stream — see the module docs in
//! [`crate::lint`] for the contract each rule enforces and the fixtures
//! in `fixtures.rs` for the exact behavior pinned by self-tests.

use super::{Emitter, FileCtx};
use crate::util::rustlex::{Tok, TokKind};
use std::collections::BTreeSet;

/// Iteration methods whose visit order on a hash collection is
/// nondeterministic across runs/platforms.
const HASH_ITERS: &[&str] = &[
    "iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter", "retain",
    "retain_mut",
];

/// Directories R1 scopes to: everywhere the event stream, scheduling
/// decisions, or report contents are produced.
const R1_DIRS: &[&str] = &["sim/", "sched/", "cluster/", "registry/"];

/// Identifiers that reach for ambient nondeterminism directly.
const AMBIENT_IDENTS: &[&str] =
    &["SystemTime", "thread_rng", "from_entropy", "RandomState", "getrandom"];

/// Files allowed to contain `unsafe` (the lane-pool internals only).
const UNSAFE_ALLOWED: &[&str] = &["sim/shard.rs"];

/// Compound-assignment operators R4 treats as accumulation.
const ACC_OPS: &[&str] = &["+=", "-=", "*=", "/="];

const R1_MSG: &str = "hash-order iteration escapes; collect-then-sort and annotate \
                      `// det: sorted(<key>)`, or use BTreeMap";

/// Index of the token closing the bracket opened at `code[i]` (clamped
/// to the last token when unclosed — the lint never panics on bad input).
fn match_close(code: &[&Tok], i: usize, open: &str, close: &str) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < code.len() {
        if code[j].text == open {
            depth += 1;
        } else if code[j].text == close {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
        j += 1;
    }
    code.len() - 1
}

/// **R1** — hash-order escape: iteration over a `HashMap`/`HashSet` in
/// the event/scheduling/report paths. Two passes: collect identifiers
/// declared hash-typed in this file, then flag iteration sites on them.
pub(crate) fn r1_hash_order(ctx: &FileCtx<'_>, em: &mut Emitter<'_>) {
    if !R1_DIRS.iter().any(|d| ctx.rel.starts_with(d)) {
        return;
    }
    let code = &ctx.code;
    let n = code.len();

    // Pass A: names declared `: [&|mut|std::collections::]Hash{Map,Set}`
    // or initialized from `Hash{Map,Set}::…`.
    let mut tracked: BTreeSet<&str> = BTreeSet::new();
    let allowed_mid = ["std", "collections", "::", "&", "mut"];
    for i in 0..n {
        let t = code[i];
        if t.kind == TokKind::Ident && i + 2 < n && code[i + 1].text == ":" {
            let mut j = i + 2;
            let mut hops = 0;
            while j < n && hops < 6 {
                let tx = code[j].text.as_str();
                if tx == "HashMap" || tx == "HashSet" {
                    tracked.insert(t.text.as_str());
                    break;
                }
                if !allowed_mid.contains(&tx) {
                    break;
                }
                j += 1;
                hops += 1;
            }
        }
        if t.text == "let" {
            let mut j = i + 1;
            if j < n && code[j].text == "mut" {
                j += 1;
            }
            if j + 1 < n && code[j].kind == TokKind::Ident && code[j + 1].text == "=" {
                let name = code[j].text.as_str();
                let hi = (j + 10).min(n.saturating_sub(1));
                for k in (j + 2)..hi {
                    let tx = code[k].text.as_str();
                    if (tx == "HashMap" || tx == "HashSet") && code[k + 1].text == "::" {
                        tracked.insert(name);
                        break;
                    }
                    if tx == ";" {
                        break;
                    }
                }
            }
        }
    }

    // Pass B: flag iteration sites on tracked names.
    for i in 0..n {
        let t = code[i];
        if ctx.in_test(t.line) {
            continue;
        }
        // ident . m (
        if t.kind == TokKind::Ident
            && tracked.contains(t.text.as_str())
            && i + 3 < n
            && code[i + 1].text == "."
            && HASH_ITERS.contains(&code[i + 2].text.as_str())
            && code[i + 3].text == "("
        {
            let token = format!("{}.{}()", t.text, code[i + 2].text);
            em.emit(code[i + 2].line, "R1", &token, R1_MSG);
        }
        // for … in [&][mut][self .] ident {
        if t.text == "for" {
            let mut depth = 0i32;
            let mut j = i + 1;
            while j < n {
                let tx = code[j].text.as_str();
                if tx == "(" || tx == "[" || tx == "{" {
                    depth += 1;
                } else if tx == ")" || tx == "]" || tx == "}" {
                    depth -= 1;
                } else if tx == "in" && depth == 0 {
                    break;
                }
                j += 1;
            }
            if j >= n {
                continue;
            }
            j += 1;
            if j < n && code[j].text == "&" {
                j += 1;
            }
            if j < n && code[j].text == "mut" {
                j += 1;
            }
            if j + 1 < n && code[j].text == "self" && code[j + 1].text == "." {
                j += 2;
            }
            if j + 1 < n
                && code[j].kind == TokKind::Ident
                && tracked.contains(code[j].text.as_str())
                && code[j + 1].text == "{"
            {
                let token = format!("for _ in {}", code[j].text);
                em.emit(code[j].line, "R1", &token, R1_MSG);
            }
        }
    }
}

/// **R2** — ambient nondeterminism: wall clocks, the process
/// environment, and OS randomness must stay in `main.rs`, `testing/`,
/// and benches; simulation results may depend only on seeds and inputs.
pub(crate) fn r2_ambient(ctx: &FileCtx<'_>, em: &mut Emitter<'_>) {
    if ctx.rel == "main.rs" || ctx.rel.starts_with("testing/") {
        return;
    }
    let code = &ctx.code;
    let n = code.len();
    for i in 0..n {
        let t = code[i];
        if ctx.in_test(t.line) {
            continue;
        }
        if t.text == "Instant" && i + 2 < n && code[i + 1].text == "::" && code[i + 2].text == "now"
        {
            em.emit(t.line, "R2", "Instant::now", "ambient wall-clock in simulation code");
        } else if t.text == "std" && i + 2 < n && code[i + 1].text == "::" && code[i + 2].text == "env"
        {
            em.emit(t.line, "R2", "std::env", "ambient environment access in simulation code");
        } else if t.kind == TokKind::Ident && AMBIENT_IDENTS.contains(&t.text.as_str()) {
            em.emit(t.line, "R2", &t.text, "ambient nondeterminism source in simulation code");
        }
    }
}

/// **R3** — unsafe hygiene: every `unsafe` block/impl carries a
/// `SAFETY:` comment within the preceding 12 lines, and `unsafe` stays
/// confined to the allowlisted pool internals. Applies everywhere,
/// tests included.
pub(crate) fn r3_unsafe(ctx: &FileCtx<'_>, em: &mut Emitter<'_>) {
    for t in &ctx.code {
        if t.text != "unsafe" {
            continue;
        }
        if !UNSAFE_ALLOWED.iter().any(|sfx| ctx.rel.ends_with(sfx)) {
            let msg = format!(
                "unsafe outside the allowlisted files ({})",
                UNSAFE_ALLOWED.join(", ")
            );
            em.emit(t.line, "R3", "unsafe", &msg);
        }
        let has_safety = ctx
            .comments
            .iter()
            .any(|c| c.text.contains("SAFETY:") && c.line + 12 >= t.line && c.line <= t.line);
        if !has_safety {
            em.emit(
                t.line,
                "R3",
                "unsafe",
                "unsafe without a SAFETY: comment in the preceding 12 lines",
            );
        }
    }
}

/// **R4** — no accumulation into captured state inside closures handed
/// to the lane pool (`par_fill`, `par_fill_rows`, `*pool.run`): chunk
/// claim order is scheduling-dependent, so `captured += x` inside a
/// worker closure is order-sensitive (float addition does not commute
/// bitwise). Reductions belong coordinator-side, in node order.
pub(crate) fn r4_pool_accumulation(ctx: &FileCtx<'_>, em: &mut Emitter<'_>) {
    if ctx.rel.starts_with("testing/") {
        return;
    }
    let code = &ctx.code;
    let n = code.len();

    // Call heads: the `(` opening a pool fan-out call.
    let mut heads: Vec<usize> = Vec::new();
    for i in 0..n {
        let t = code[i];
        if ctx.in_test(t.line) {
            continue;
        }
        if t.kind == TokKind::Ident
            && (t.text == "par_fill" || t.text == "par_fill_rows")
            && i + 1 < n
            && code[i + 1].text == "("
        {
            heads.push(i + 1);
        }
        if t.text == "run"
            && i >= 2
            && code[i - 1].text == "."
            && code[i - 2].kind == TokKind::Ident
            && code[i - 2].text.ends_with("pool")
            && i + 1 < n
            && code[i + 1].text == "("
        {
            heads.push(i + 1);
        }
    }

    for &h in &heads {
        let end = match_close(code, h, "(", ")");
        let mut j = h + 1;
        while j < end {
            let tx = code[j].text.as_str();
            let opens_closure = (tx == "|" || tx == "||")
                && matches!(code[j - 1].text.as_str(), "&" | "(" | ",");
            if opens_closure {
                // Closure parameter names are locally bound.
                let mut locals: BTreeSet<&str> = BTreeSet::new();
                let body_start = if tx == "||" {
                    j + 1
                } else {
                    let mut k = j + 1;
                    while k < end && code[k].text != "|" {
                        if code[k].kind == TokKind::Ident {
                            locals.insert(code[k].text.as_str());
                        }
                        k += 1;
                    }
                    k + 1
                };
                // Body extent: a brace block, or an expression up to the
                // next top-level `,`/`)`.
                let body_end = if body_start < end && code[body_start].text == "{" {
                    match_close(code, body_start, "{", "}")
                } else {
                    let mut k = body_start;
                    let mut depth = 0i32;
                    while k < end {
                        let t2 = code[k].text.as_str();
                        if t2 == "(" || t2 == "[" || t2 == "{" {
                            depth += 1;
                        } else if t2 == ")" || t2 == "]" || t2 == "}" {
                            depth -= 1;
                        } else if t2 == "," && depth == 0 {
                            break;
                        }
                        k += 1;
                    }
                    k
                };
                // `let` and `for` bindings inside the body are local too.
                for k in body_start..body_end {
                    if code[k].text == "let" {
                        let mut m = k + 1;
                        while m < body_end && code[m].text != "=" && code[m].text != ";" {
                            if code[m].kind == TokKind::Ident && code[m].text != "mut" {
                                locals.insert(code[m].text.as_str());
                            }
                            m += 1;
                        }
                    }
                    if code[k].text == "for" {
                        let mut m = k + 1;
                        while m < body_end && code[m].text != "in" {
                            if code[m].kind == TokKind::Ident && code[m].text != "mut" {
                                locals.insert(code[m].text.as_str());
                            }
                            m += 1;
                        }
                    }
                }
                // Flag compound assignment whose LHS root is captured.
                for k in body_start..body_end {
                    if !ACC_OPS.contains(&code[k].text.as_str()) {
                        continue;
                    }
                    let mut m = k as i64 - 1;
                    let mut root: Option<&Tok> = None;
                    while m >= body_start as i64 {
                        let tm = code[m as usize];
                        let t2 = tm.text.as_str();
                        if tm.kind == TokKind::Ident || t2 == "self" {
                            root = Some(tm);
                            m -= 1;
                        } else if t2 == "." || t2 == "*" {
                            m -= 1;
                        } else if t2 == "]" || t2 == ")" {
                            // Skip the bracket group backwards.
                            let open = if t2 == "]" { "[" } else { "(" };
                            let mut depth = 0i32;
                            while m >= body_start as i64 {
                                if code[m as usize].text == t2 {
                                    depth += 1;
                                } else if code[m as usize].text == open {
                                    depth -= 1;
                                    if depth == 0 {
                                        break;
                                    }
                                }
                                m -= 1;
                            }
                            m -= 1;
                        } else {
                            break;
                        }
                    }
                    let Some(r) = root else { continue };
                    if locals.contains(r.text.as_str()) {
                        continue;
                    }
                    let token = format!("{} .. {}", r.text, code[k].text);
                    em.emit(
                        code[k].line,
                        "R4",
                        &token,
                        "accumulation into captured state inside a pool closure; \
                         reduce coordinator-side in node order",
                    );
                }
                j = body_end;
            }
            j += 1;
        }
    }
}
