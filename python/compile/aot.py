"""AOT bridge: lower the L2 scoring pipeline to HLO *text* per shape
variant for the rust PJRT runtime.

HLO text — not ``lowered.compile()`` output or a serialized
HloModuleProto — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts/scorer.hlo.txt
Writes one artifact per variant next to the requested path, plus a
manifest.json describing the shapes for the rust loader.

Python runs only here, at build time (`make artifacts`); the rust binary
is self-contained afterwards.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import VARIANTS, example_args, score_pipeline


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n_nodes: int, n_layers: int) -> str:
    lowered = jax.jit(score_pipeline).lower(*example_args(n_nodes, n_layers))
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out",
        default="../artifacts/scorer.hlo.txt",
        help="base artifact path; per-variant files derive from it",
    )
    args = parser.parse_args()
    base, ext = os.path.splitext(args.out)
    if base.endswith(".hlo"):
        base = base[: -len(".hlo")]
        ext = ".hlo" + ext
    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "outputs": ["final", "layer", "omega", "best"], "variants": []}
    for name, n_nodes, n_layers in VARIANTS:
        text = lower_variant(n_nodes, n_layers)
        path = f"{base}_{name}{ext}"
        with open(path, "w") as f:
            f.write(text)
        manifest["variants"].append(
            {
                "name": name,
                "n_nodes": n_nodes,
                "n_layers": n_layers,
                "file": os.path.basename(path),
            }
        )
        print(f"wrote {path} ({len(text)} chars, N={n_nodes}, L={n_layers})")

    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
