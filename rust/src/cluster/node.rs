//! Edge nodes: capacities (CPU, memory, disk, bandwidth), taints and labels,
//! and the local image/layer inventory the layer-aware scheduler reads
//! (paper §III-A "each node maintains running containers, local images, and
//! local layers").

use super::pod::PodId;
use super::resources::Resources;
use crate::registry::{ImageRef, LayerId, LayerSet};
use crate::util::units::{Bandwidth, Bytes};
use std::collections::BTreeMap;

/// Dense node identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

/// Node lifecycle status (edge clusters are volatile: nodes join, drain,
/// and crash mid-run — EdgePier-style churn the simulator injects as
/// events).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeStatus {
    /// Accepting new pods.
    #[default]
    Ready,
    /// Cordoned: running pods finish, no new bindings (kubectl drain).
    Draining,
    /// Crashed/unreachable: pods lost, inventory gone.
    Down,
}

/// A node taint (key=value); pods need a matching toleration or the
/// TaintToleration plugin deprioritizes/filters the node.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Taint {
    /// Taint key.
    pub key: String,
    /// Taint value (tolerations match key and value exactly).
    pub value: String,
    /// Hard taints filter (NoSchedule); soft taints only lower the score
    /// (PreferNoSchedule) — both exist in Kubernetes and the paper's plugin
    /// list includes the scoring form.
    pub hard: bool,
}

/// Per-layer use metadata the pluggable cache policies read
/// (`sim/cache.rs`): LRU timestamps and decayed popularity weights,
/// maintained by the engine at bind/install time and pruned on eviction.
/// The fixed `PressureSweep` policy never reads it, so maintaining it is
/// invisible to the pre-policy byte-identity fingerprints.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LayerUse {
    /// Virtual time the layer was last required by a pod bind or install.
    pub last_use: f64,
    /// Arrival-frequency popularity weight as of `pop_at` (decay it to
    /// the read time with [`crate::sim::cache::decayed`]).
    pub popularity: f64,
    /// Virtual time `popularity` was last bumped.
    pub pop_at: f64,
}

/// An edge node.
#[derive(Debug, Clone)]
pub struct Node {
    /// Dense node id (row index in dense scoring).
    pub id: NodeId,
    /// Human-readable node name (e.g. `worker1`, `edge042`).
    pub name: String,
    /// Allocatable resources (paper: CPU cores p_n, memory e_n).
    pub capacity: Resources,
    /// Disk capacity d_n for image layers.
    pub disk: Bytes,
    /// Downlink bandwidth b_n to the registry.
    pub bandwidth: Bandwidth,
    /// Max simultaneously running containers C_n.
    pub max_containers: usize,
    /// Node labels (selectors and affinity terms match against these).
    pub labels: BTreeMap<String, String>,
    /// Node taints (see [`Taint`]).
    pub taints: Vec<Taint>,
    /// Free disk the VolumeBinding plugin can bind against.
    pub volume_capacity: Bytes,
    /// Lifecycle status; non-Ready nodes are filtered from scheduling.
    pub status: NodeStatus,

    // --- mutable inventory (the t-dependent sets of §III-A) --------------
    /// Requested resources of all pods assigned here (p_n(t), e_n(t)).
    pub used: Resources,
    /// Pods currently assigned (C_n(t)).
    pub pods: Vec<PodId>,
    /// Local images M_n(t).
    pub images: Vec<ImageRef>,
    /// Local layers L_n(t) as an interned bitset.
    pub layers: LayerSet,
    /// Bumped whenever `layers` changes (install/evict). Dense-scoring
    /// arenas use it to skip refilling unchanged presence rows; mutate
    /// `layers` through [`crate::cluster::ClusterState`] so it stays true.
    pub layers_version: u64,
    /// Bytes of disk consumed by local layers.
    pub disk_used: Bytes,
    /// Per-layer use metadata for the pluggable cache policies
    /// (`sim/cache.rs`): a `BTreeMap` so every walk is in layer-id order.
    pub cache_meta: BTreeMap<LayerId, LayerUse>,
}

impl Node {
    /// A Ready node with empty inventory and kubelet-default max pods.
    pub fn new(id: NodeId, name: &str, capacity: Resources, disk: Bytes, bandwidth: Bandwidth) -> Node {
        Node {
            id,
            name: name.to_string(),
            capacity,
            disk,
            bandwidth,
            max_containers: 110, // kubelet default maxPods
            labels: BTreeMap::new(),
            taints: Vec::new(),
            volume_capacity: disk,
            status: NodeStatus::Ready,
            used: Resources::ZERO,
            pods: Vec::new(),
            images: Vec::new(),
            layers: LayerSet::new(),
            layers_version: 0,
            disk_used: Bytes::ZERO,
            cache_meta: BTreeMap::new(),
        }
    }

    /// Builder: add a label.
    pub fn with_label(mut self, key: &str, value: &str) -> Node {
        self.labels.insert(key.to_string(), value.to_string());
        self
    }

    /// Builder: add a taint (`hard` = NoSchedule, else PreferNoSchedule).
    pub fn with_taint(mut self, key: &str, value: &str, hard: bool) -> Node {
        self.taints.push(Taint { key: key.to_string(), value: value.to_string(), hard });
        self
    }

    /// Builder: override the max simultaneously running containers.
    pub fn with_max_containers(mut self, n: usize) -> Node {
        self.max_containers = n;
        self
    }

    /// Can the scheduler bind new pods here?
    pub fn is_schedulable(&self) -> bool {
        self.status == NodeStatus::Ready
    }

    /// Is the node alive (Ready or Draining — its pods keep running)?
    pub fn is_up(&self) -> bool {
        self.status != NodeStatus::Down
    }

    /// Resources still schedulable.
    pub fn available(&self) -> Resources {
        self.capacity.saturating_sub(&self.used)
    }

    /// CPU and memory utilisation fractions (p_n(t)/p_n, e_n(t)/e_n).
    pub fn utilisation(&self) -> (f64, f64) {
        self.used.fraction_of(&self.capacity)
    }

    /// Free disk for new layers.
    pub fn disk_free(&self) -> Bytes {
        self.disk.saturating_sub(self.disk_used)
    }

    /// Does this node already hold the image (ImageLocality's fast path)?
    pub fn has_image(&self, image: &ImageRef) -> bool {
        self.images.iter().any(|i| i == image)
    }

    /// Assign a pod: reserve resources and record membership.
    pub fn assign(&mut self, pod: PodId, requests: Resources) {
        self.used += requests;
        self.pods.push(pod);
    }

    /// Release a pod's resources (scale-down / completion).
    pub fn release(&mut self, pod: PodId, requests: Resources) {
        self.used = self.used.saturating_sub(&requests);
        self.pods.retain(|&p| p != pod);
    }

    /// Record a demand for `layer` at virtual time `now` (a pod that needs
    /// it was bound here): decays the popularity weight to `now`, bumps it
    /// by one arrival, and refreshes the LRU timestamp. `decay` is the
    /// popularity time constant in seconds (`--cache-decay`).
    pub fn touch_layer(&mut self, layer: LayerId, now: f64, decay: f64) {
        let u = self.cache_meta.entry(layer).or_default();
        u.popularity = crate::sim::cache::decayed(u.popularity, u.pop_at, now, decay) + 1.0;
        u.pop_at = now;
        u.last_use = now;
    }

    /// Refresh only the LRU timestamp for `layer` (layer install/prefetch
    /// completed at virtual time `now`).
    pub fn touch_layer_install(&mut self, layer: LayerId, now: f64) {
        self.cache_meta.entry(layer).or_default().last_use = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> Node {
        Node::new(
            NodeId(0),
            "worker1",
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(30.0),
            Bandwidth::from_mbps(10.0),
        )
    }

    #[test]
    fn available_and_utilisation() {
        let mut n = node();
        assert_eq!(n.available(), n.capacity);
        n.assign(PodId(1), Resources::cores_gb(1.0, 2.0));
        let (cpu, mem) = n.utilisation();
        assert!((cpu - 0.25).abs() < 1e-12);
        assert!((mem - 0.5).abs() < 1e-12);
        assert_eq!(n.available(), Resources::cores_gb(3.0, 2.0));
        assert_eq!(n.pods, vec![PodId(1)]);
    }

    #[test]
    fn release_restores() {
        let mut n = node();
        let r = Resources::cores_gb(2.0, 1.0);
        n.assign(PodId(7), r);
        n.release(PodId(7), r);
        assert_eq!(n.used, Resources::ZERO);
        assert!(n.pods.is_empty());
    }

    #[test]
    fn disk_accounting() {
        let mut n = node();
        assert_eq!(n.disk_free(), Bytes::from_gb(30.0));
        n.disk_used = Bytes::from_gb(29.0);
        assert_eq!(n.disk_free(), Bytes::from_gb(1.0));
    }

    #[test]
    fn status_gates_schedulability() {
        let mut n = node();
        assert!(n.is_schedulable() && n.is_up());
        n.status = NodeStatus::Draining;
        assert!(!n.is_schedulable() && n.is_up());
        n.status = NodeStatus::Down;
        assert!(!n.is_schedulable() && !n.is_up());
    }

    #[test]
    fn taints_and_labels() {
        let n = node().with_label("zone", "a").with_taint("edge", "unstable", false);
        assert_eq!(n.labels.get("zone").map(|s| s.as_str()), Some("a"));
        assert_eq!(n.taints.len(), 1);
        assert!(!n.taints[0].hard);
    }
}
