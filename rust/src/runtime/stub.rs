//! Stub XLA scorer for builds without the `xla` feature. Keeps the public
//! surface of `runtime::scorer::XlaScorer` so callers compile unchanged;
//! both loaders return [`XlaUnavailable`], and the [`ScoringBackend`] impl
//! (reachable only by constructing through a loader, i.e. never) delegates
//! to the native scorer.

use crate::sched::scoring::{NativeScorer, ScoreInputs, ScoreOutputs, ScoringBackend};
use std::path::Path;

/// Error returned by the stub loaders.
#[derive(Debug, Clone)]
pub struct XlaUnavailable;

impl std::fmt::Display for XlaUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "xla backend not compiled in (build with `--features xla` and the \
             xla/anyhow crates available, then run `make artifacts`)"
        )
    }
}

impl std::error::Error for XlaUnavailable {}

/// Execution statistics — mirrors `scorer::ScorerStats`.
#[derive(Debug, Clone, Default)]
pub struct ScorerStats {
    /// Successful XLA executions (always 0 in the stub).
    pub executions: u64,
    /// Cycles served by the native scorer instead.
    pub native_fallbacks: u64,
    /// Executions per compiled variant (always empty in the stub).
    pub per_variant: Vec<u64>,
}

/// Stub of the XLA-backed scorer; cannot actually be constructed because
/// both loaders fail, which is exactly what downstream `match`/`?` sites
/// expect when artifacts or the PJRT toolchain are absent.
pub struct XlaScorer {
    native: NativeScorer,
    /// Execution statistics (observability parity with the real scorer).
    pub stats: ScorerStats,
}

impl XlaScorer {
    /// Mirrors `scorer::XlaScorer::load`; always unavailable in the stub.
    pub fn load(_artifacts_dir: &Path) -> Result<XlaScorer, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Mirrors `scorer::XlaScorer::load_default`; always unavailable.
    pub fn load_default() -> Result<XlaScorer, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Compiled shape variants (always empty in the stub).
    pub fn variant_names(&self) -> Vec<&str> {
        Vec::new()
    }
}

impl ScoringBackend for XlaScorer {
    fn name(&self) -> &'static str {
        "xla-stub"
    }

    fn score(&mut self, inputs: &ScoreInputs) -> ScoreOutputs {
        self.stats.native_fallbacks += 1;
        self.native.score(inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loaders_report_unavailable() {
        assert!(XlaScorer::load_default().is_err());
        assert!(XlaScorer::load(Path::new("artifacts")).is_err());
        let msg = XlaScorer::load_default().unwrap_err().to_string();
        assert!(msg.contains("xla"));
    }
}
