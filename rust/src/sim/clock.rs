//! Virtual clock for the discrete-event simulator.

/// Monotonic virtual time in seconds.
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now: f64,
}

impl Clock {
    /// A clock at t = 0.
    pub fn new() -> Clock {
        Clock { now: 0.0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to an absolute time; never moves backwards.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t >= self.now - 1e-9, "clock moved backwards: {} -> {}", self.now, t);
        if t > self.now {
            self.now = t;
        }
    }

    /// Advance by a non-negative delta.
    pub fn advance_by(&mut self, dt: f64) {
        assert!(dt >= 0.0, "negative advance {dt}");
        self.now += dt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances() {
        let mut c = Clock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_by(2.5);
        c.advance_to(4.0);
        assert_eq!(c.now(), 4.0);
        c.advance_to(4.0); // idempotent
        assert_eq!(c.now(), 4.0);
    }

    #[test]
    #[should_panic]
    fn backwards_panics() {
        let mut c = Clock::new();
        c.advance_to(5.0);
        c.advance_to(1.0);
    }
}
