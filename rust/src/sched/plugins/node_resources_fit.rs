//! NodeResourcesFit — "verifies if the node has all the resources requested
//! by the container. The default strategy is LeastAllocated" (paper §IV-B).
//!
//! Filter: pod requests must fit the node's remaining allocatable.
//! Score: LeastAllocated — `((cap - used - req) / cap)` averaged over CPU
//! and memory, scaled to 0–100 (upstream `leastResourceScorer`).

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{FilterPlugin, FilterResult, ScorePlugin, MAX_NODE_SCORE};

/// NodeResourcesFit filter: requests must fit the node's allocatable
/// resources (Eqs. 6–7).
pub struct NodeResourcesFit;

impl FilterPlugin for NodeResourcesFit {
    fn name(&self) -> &'static str {
        "NodeResourcesFit"
    }

    fn filter(&self, ctx: &CycleContext, node: &Node) -> FilterResult {
        let avail = node.available();
        if !ctx.pod.requests.fits_within(&avail) {
            return FilterResult::Reject(format!(
                "insufficient resources: requested {:?}, available cpu={} mem={}",
                ctx.pod.requests, avail.cpu, avail.memory
            ));
        }
        FilterResult::Pass
    }
}

/// LeastAllocated scoring strategy.
pub struct LeastAllocated;

impl ScorePlugin for LeastAllocated {
    fn name(&self) -> &'static str {
        "NodeResourcesFit/LeastAllocated"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        let after = node.used.checked_add(&ctx.pod.requests);
        let (cpu_frac, mem_frac) = after.fraction_of(&node.capacity);
        let cpu_score = (1.0 - cpu_frac.min(1.0)) * MAX_NODE_SCORE;
        let mem_score = (1.0 - mem_frac.min(1.0)) * MAX_NODE_SCORE;
        (cpu_score + mem_score) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    fn node(cores: f64, gb: f64) -> Node {
        Node::new(
            NodeId(0),
            "n",
            Resources::cores_gb(cores, gb),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        )
    }

    #[test]
    fn filter_rejects_overcommit() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::cores_gb(2.0, 2.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let mut n = node(4.0, 4.0);
        assert_eq!(NodeResourcesFit.filter(&ctx, &n), FilterResult::Pass);
        n.used = Resources::cores_gb(3.0, 0.0);
        assert!(matches!(NodeResourcesFit.filter(&ctx, &n), FilterResult::Reject(_)));
    }

    #[test]
    fn least_allocated_prefers_idle() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::cores_gb(1.0, 1.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let idle = node(4.0, 4.0);
        let mut busy = node(4.0, 4.0);
        busy.used = Resources::cores_gb(2.0, 2.0);
        let si = LeastAllocated.score(&ctx, &idle);
        let sb = LeastAllocated.score(&ctx, &busy);
        assert!(si > sb);
        // idle: after = 1/4 = 25% each dim → score 75.
        assert!((si - 75.0).abs() < 1e-9);
        assert!((sb - 25.0).abs() < 1e-9);
    }

    #[test]
    fn score_never_negative() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::cores_gb(8.0, 8.0));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let n = node(4.0, 4.0); // pod bigger than node (filter would reject)
        assert_eq!(LeastAllocated.score(&ctx, &n), 0.0);
    }
}
