//! Tiny leveled logger (the `log`/`env_logger` stack is not vendored with
//! an emitter). Level is process-global and settable from the CLI
//! (`--log-level`) or the `LRSCHED_LOG` environment variable.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Initialize from `LRSCHED_LOG` if set (error|warn|info|debug|trace).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("LRSCHED_LOG") {
        if let Some(l) = parse_level(&v) {
            set_level(l);
        }
    }
}

pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

pub fn enabled(level: Level) -> bool {
    level <= self::level()
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
