//! End-to-end trace-replay tests against the bundled fixtures: importer
//! counts, strict-mode acceptance, full engine replays (with and without
//! churn) satisfying the terminal-outcome accounting identity, and
//! byte-identical determinism across runs — the PR 3 acceptance criteria.

use lrsched::exp::common;
use lrsched::sim::{
    trace, ChurnConfig, ErrorMode, SimConfig, SimReport, Simulation, TraceFormat, TraceOptions,
};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn load_fixture(name: &str, format: TraceFormat, mode: ErrorMode) -> trace::Trace {
    let opts = TraceOptions { format, mode, ..Default::default() };
    trace::load(&fixture(name), &opts).expect("fixture parses")
}

/// Replay a fixture through the engine and return (report, event-log
/// digest, virtual end time).
fn replay(
    name: &str,
    format: TraceFormat,
    speedup: f64,
    churn: Option<ChurnConfig>,
) -> (SimReport, String, f64) {
    let opts = TraceOptions { format, speedup, ..Default::default() };
    let t = trace::load(&fixture(name), &opts).expect("fixture parses");
    let registry = t.synthesize_registry();
    let arrivals = t.arrivals();
    let mut cfg = SimConfig::default();
    cfg.inter_arrival_secs = Some(0.3); // timed mode; offsets are explicit
    cfg.gc_enabled = true;
    cfg.retry_limit = 10;
    cfg.snapshot_every = 10;
    cfg.churn = churn;
    let mut sim = Simulation::new(common::scale_nodes(8), registry, cfg);
    let report = sim.run_arrivals(arrivals);
    sim.state.check_invariants().expect("cluster invariants");
    (report, format!("{:?}", sim.events.all()), sim.clock.now())
}

fn assert_balanced(report: &SimReport) {
    assert!(
        report.accounting_balanced(),
        "completed {} + failed {} + unschedulable {} + lost {} != submitted {}",
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.lost_to_crash,
        report.submitted
    );
}

#[test]
fn alibaba_fixture_counts() {
    let t = load_fixture("alibaba_mini.csv", TraceFormat::Alibaba, ErrorMode::Lenient);
    assert_eq!(t.stats.rows, 36);
    assert_eq!(t.stats.events, 53, "instance_num expansion");
    assert_eq!(t.stats.apps, 8);
    assert_eq!(t.stats.skipped, 0);
    assert_eq!(t.stats.duplicates, 0);
    assert!(!t.stats.resorted, "fixture is time-sorted");
    assert!((t.stats.span_secs - 600.0).abs() < 1e-9);
    // Forever-running service rows have no duration.
    assert!(t.events.iter().any(|e| e.duration_secs.is_none()));
    // Zero-duration probes survive import.
    assert!(t.events.iter().any(|e| e.duration_secs == Some(0.0)));
}

#[test]
fn azure_fixture_counts() {
    let t = load_fixture("azure_mini.csv", TraceFormat::Azure, ErrorMode::Lenient);
    assert_eq!(t.stats.rows, 25);
    assert_eq!(t.stats.events, 25);
    assert_eq!(t.stats.apps, 4, "type_web/type_db/type_batch/type_cache");
    assert_eq!(t.stats.skipped, 0);
    // vm0002's negative start clamps to the window start.
    assert_eq!(t.events.iter().filter(|e| e.submit_at == 0.0).count(), 2);
}

#[test]
fn fixtures_pass_strict_mode() {
    // The bundled fixtures are clean: sorted, duplicate-free, well-formed.
    load_fixture("alibaba_mini.csv", TraceFormat::Alibaba, ErrorMode::Strict);
    load_fixture("azure_mini.csv", TraceFormat::Azure, ErrorMode::Strict);
}

#[test]
fn gzipped_trace_matches_plain_import() {
    // `--trace foo.csv.gz` inflates in memory and must import exactly as
    // the plain file (real traces ship gzipped, e.g. batch_task.csv.gz).
    let plain = load_fixture("alibaba_mini.csv", TraceFormat::Alibaba, ErrorMode::Strict);
    let gz = load_fixture("alibaba_mini.csv.gz", TraceFormat::Alibaba, ErrorMode::Strict);
    assert_eq!(format!("{:?}", plain.stats), format!("{:?}", gz.stats));
    assert_eq!(plain.events.len(), gz.events.len());
    assert_eq!(format!("{:?}", plain.events), format!("{:?}", gz.events));
    // And the replay downstream of the import is byte-identical too.
    let (r1, ev1, t1) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, None);
    let (r2, ev2, t2) = replay("alibaba_mini.csv.gz", TraceFormat::Alibaba, 1.0, None);
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"));
    assert_eq!(ev1, ev2);
    assert_eq!(t1, t2);
}

#[test]
fn corrupt_gz_is_an_io_error_not_a_panic() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("lrsched-corrupt-{}.csv.gz", std::process::id()));
    std::fs::write(&path, b"not actually gzip data").unwrap();
    let opts = TraceOptions::default();
    let err = trace::load(&path, &opts).unwrap_err();
    let _ = std::fs::remove_file(&path);
    assert!(
        format!("{err}").contains("gzip"),
        "gz decode failures must surface as trace I/O errors: {err}"
    );
}

#[test]
fn alibaba_replay_balances_accounting() {
    let (report, _, _) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, None);
    assert_eq!(report.submitted, 53);
    assert_balanced(&report);
    assert!(report.completed() > 0);
    // Popularity skew: repeated apps reuse layers, so replays after the
    // first pull of an image download less than a cold pull each time.
    assert!(report.records.iter().any(|r| r.download.0 == 0));
}

#[test]
fn azure_replay_balances_accounting() {
    // 10x speedup keeps the fractional-day timeline short.
    let (report, _, _) = replay("azure_mini.csv", TraceFormat::Azure, 10.0, None);
    assert_eq!(report.submitted, 25);
    assert_balanced(&report);
    assert!(report.completed() > 0);
}

#[test]
fn alibaba_replay_is_byte_identical_across_runs() {
    let (r1, ev1, t1) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, None);
    let (r2, ev2, t2) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, None);
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"), "report must be byte-identical");
    assert_eq!(ev1, ev2, "event log must be byte-identical");
    assert_eq!(t1, t2);
}

#[test]
fn azure_replay_is_byte_identical_across_runs() {
    let (r1, ev1, _) = replay("azure_mini.csv", TraceFormat::Azure, 10.0, None);
    let (r2, ev2, _) = replay("azure_mini.csv", TraceFormat::Azure, 10.0, None);
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"));
    assert_eq!(ev1, ev2);
}

#[test]
fn churn_replay_is_byte_identical_and_balanced() {
    let churn = || {
        Some(ChurnConfig {
            seed: 5,
            horizon_secs: 600.0,
            joins: 2,
            drains: 1,
            crash_fraction: 0.25,
            outages: 1,
            outage_secs: 30.0,
            ..Default::default()
        })
    };
    let (r1, ev1, _) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, churn());
    let (r2, ev2, _) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, churn());
    assert_eq!(r1.submitted, 53);
    assert_eq!(r1.nodes_crashed, 2, "25% of 8 nodes");
    assert_eq!(r1.nodes_joined, 2);
    assert_eq!(r1.nodes_drained, 1);
    assert_balanced(&r1);
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"), "churn replay must be deterministic");
    assert_eq!(ev1, ev2);
}

#[test]
fn speedup_compresses_virtual_time() {
    let (r1, _, end1) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 1.0, None);
    let (r10, _, end10) = replay("alibaba_mini.csv", TraceFormat::Alibaba, 10.0, None);
    assert_eq!(r1.submitted, r10.submitted);
    assert_balanced(&r10);
    assert!(
        end10 < end1,
        "10x speedup must shorten the virtual timeline: {end10} !< {end1}"
    );
}

#[test]
fn limit_bounds_replay() {
    let opts = TraceOptions { limit: Some(10), ..Default::default() };
    let t = trace::load(&fixture("alibaba_mini.csv"), &opts).expect("parses");
    assert_eq!(t.events.len(), 10);
    let registry = t.synthesize_registry();
    let arrivals = t.arrivals();
    let mut sim = Simulation::new(common::scale_nodes(4), registry, SimConfig::default());
    let report = sim.run_arrivals(arrivals);
    assert_eq!(report.submitted, 10);
    assert_balanced(&report);
}
