//! Foundation substrates built in-repo because the vendored dependency set
//! has no serde/rand/clap/flate2 equivalents: JSON, RNG, statistics,
//! logging, gzip/DEFLATE decompression, a Rust token lexer (for the
//! `lint` determinism checker), and resource-unit newtypes.

pub mod gzip;
pub mod json;
pub mod logging;
pub mod rng;
pub mod rustlex;
pub mod stats;
pub mod units;
