//! NodeAffinity — "implements node selectors and affinity, scoring nodes
//! higher that meet more affinity conditions" (paper §IV-B).
//!
//! Filter: `nodeSelector` labels and `required` affinity terms must match.
//! Score: sum of matched `preferred` term weights, normalized by max.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{normalize_by_max, FilterPlugin, FilterResult, ScorePlugin};

fn term_matches(node: &Node, key: &str, values: &[String]) -> bool {
    node.labels
        .get(key)
        .map(|v| values.iter().any(|want| want == v))
        .unwrap_or(false)
}

/// NodeAffinity filter: hard node-selector and required affinity terms.
pub struct NodeAffinityFilter;

impl FilterPlugin for NodeAffinityFilter {
    fn name(&self) -> &'static str {
        "NodeAffinity"
    }

    fn filter(&self, ctx: &CycleContext, node: &Node) -> FilterResult {
        for (k, v) in &ctx.pod.node_selector {
            if node.labels.get(k) != Some(v) {
                return FilterResult::Reject(format!("node selector {k}={v} unmatched"));
            }
        }
        for term in &ctx.pod.affinity.required {
            if !term_matches(node, &term.key, &term.values) {
                return FilterResult::Reject(format!(
                    "required affinity {} in {:?} unmatched",
                    term.key, term.values
                ));
            }
        }
        FilterResult::Pass
    }
}

/// NodeAffinity score: weighted preferred affinity terms.
pub struct NodeAffinityScore;

impl ScorePlugin for NodeAffinityScore {
    fn name(&self) -> &'static str {
        "NodeAffinity"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        ctx.pod
            .affinity
            .preferred
            .iter()
            .filter(|t| term_matches(node, &t.key, &t.values))
            .map(|t| t.weight as f64)
            .sum()
    }

    fn normalize(&self, _ctx: &CycleContext, scores: &mut [f64]) {
        normalize_by_max(scores);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::AffinityTerm;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    fn node(id: u32) -> Node {
        Node::new(
            NodeId(id),
            &format!("n{id}"),
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        )
    }

    #[test]
    fn selector_filters() {
        let state = ClusterState::new();
        let pod = PodBuilder::new()
            .build("redis", Resources::ZERO)
            .with_selector("disk", "ssd");
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        assert!(matches!(
            NodeAffinityFilter.filter(&ctx, &node(0)),
            FilterResult::Reject(_)
        ));
        assert_eq!(
            NodeAffinityFilter.filter(&ctx, &node(1).with_label("disk", "ssd")),
            FilterResult::Pass
        );
        assert!(matches!(
            NodeAffinityFilter.filter(&ctx, &node(2).with_label("disk", "hdd")),
            FilterResult::Reject(_)
        ));
    }

    #[test]
    fn required_terms_filter() {
        let state = ClusterState::new();
        let mut pod = PodBuilder::new().build("redis", Resources::ZERO);
        pod.affinity.required.push(AffinityTerm {
            key: "zone".into(),
            values: vec!["a".into(), "b".into()],
            weight: 0,
        });
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        assert_eq!(
            NodeAffinityFilter.filter(&ctx, &node(0).with_label("zone", "b")),
            FilterResult::Pass
        );
        assert!(matches!(
            NodeAffinityFilter.filter(&ctx, &node(1).with_label("zone", "c")),
            FilterResult::Reject(_)
        ));
    }

    #[test]
    fn preferred_terms_score_by_weight() {
        let state = ClusterState::new();
        let mut pod = PodBuilder::new().build("redis", Resources::ZERO);
        pod.affinity.preferred.push(AffinityTerm {
            key: "zone".into(),
            values: vec!["a".into()],
            weight: 80,
        });
        pod.affinity.preferred.push(AffinityTerm {
            key: "disk".into(),
            values: vec!["ssd".into()],
            weight: 20,
        });
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let both = node(0).with_label("zone", "a").with_label("disk", "ssd");
        let one = node(1).with_label("zone", "a");
        let none = node(2);
        let mut scores = vec![
            NodeAffinityScore.score(&ctx, &both),
            NodeAffinityScore.score(&ctx, &one),
            NodeAffinityScore.score(&ctx, &none),
        ];
        assert_eq!(scores, vec![100.0, 80.0, 0.0]);
        NodeAffinityScore.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![100.0, 80.0, 0.0]);
    }
}
