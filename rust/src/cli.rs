//! Hand-rolled command-line parser (`clap` is not in the vendored
//! dependency set). Supports subcommands, `--flag`, `--key value`,
//! `--key=value`, and positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec for one subcommand.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name without the leading `--`.
    pub name: &'static str,
    /// One-line help text shown by `usage`.
    pub help: &'static str,
    /// None ⇒ boolean flag, Some(default) ⇒ takes a value.
    pub default: Option<&'static str>,
}

/// Parsed arguments for a subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Arguments that were not options (or followed `--`).
    pub positional: Vec<String>,
}

impl Args {
    /// Raw value of `--name` (None when the option was absent and had no
    /// non-empty default).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Was the boolean flag `--name` passed?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse `--name` as `T`, distinguishing absent (Ok(None)) from
    /// unparsable (Err).
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("invalid value for --{name}: {v:?}")),
        }
    }

    /// `--name` as usize, or `default` when absent.
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        Ok(self.get_parsed::<usize>(name)?.unwrap_or(default))
    }

    /// `--name` as u64, or `default` when absent.
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        Ok(self.get_parsed::<u64>(name)?.unwrap_or(default))
    }

    /// `--name` as f64, or `default` when absent.
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        Ok(self.get_parsed::<f64>(name)?.unwrap_or(default))
    }

    /// `--name` as a string slice, or `default` when absent.
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

/// Parse `argv` (without the program name) against a spec. Unknown options
/// are an error; `--` ends option parsing.
pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, String> {
    let mut args = Args::default();
    // Seed defaults.
    for opt in spec {
        if let Some(d) = opt.default {
            if !d.is_empty() {
                args.values.insert(opt.name.to_string(), d.to_string());
            }
        }
    }
    let mut i = 0;
    let mut opts_done = false;
    while i < argv.len() {
        let a = &argv[i];
        if opts_done || !a.starts_with("--") {
            args.positional.push(a.clone());
            i += 1;
            continue;
        }
        if a == "--" {
            opts_done = true;
            i += 1;
            continue;
        }
        let body = &a[2..];
        let (name, inline_val) = match body.split_once('=') {
            Some((n, v)) => (n, Some(v.to_string())),
            None => (body, None),
        };
        let opt = spec
            .iter()
            .find(|o| o.name == name)
            .ok_or_else(|| format!("unknown option --{name}"))?;
        match (opt.default, inline_val) {
            (None, None) => args.flags.push(name.to_string()),
            (None, Some(_)) => return Err(format!("--{name} is a flag and takes no value")),
            (Some(_), Some(v)) => {
                args.values.insert(name.to_string(), v);
            }
            (Some(_), None) => {
                i += 1;
                let v = argv
                    .get(i)
                    .ok_or_else(|| format!("--{name} requires a value"))?;
                args.values.insert(name.to_string(), v.clone());
            }
        }
        i += 1;
    }
    Ok(args)
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: lrsched {cmd} [options]\n\nOptions:\n");
    for opt in spec {
        let head = match opt.default {
            None => format!("  --{}", opt.name),
            Some("") => format!("  --{} <value>", opt.name),
            Some(d) => format!("  --{} <value> (default: {d})", opt.name),
        };
        s.push_str(&format!("{head:<46} {}\n", opt.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "nodes", help: "node count", default: Some("4") },
            OptSpec { name: "seed", help: "rng seed", default: Some("42") },
            OptSpec { name: "verbose", help: "chatty", default: None },
            OptSpec { name: "out", help: "output path", default: Some("") },
        ]
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &spec()).unwrap();
        assert_eq!(a.get("nodes"), Some("4"));
        assert_eq!(a.get("out"), None); // empty default means optional
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse(&sv(&["--nodes", "5", "--seed=7"]), &spec()).unwrap();
        assert_eq!(a.usize_or("nodes", 0).unwrap(), 5);
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&sv(&["--verbose", "pos1", "--", "--not-an-opt"]), &spec()).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "--not-an-opt"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--bogus"]), &spec()).is_err());
        assert!(parse(&sv(&["--nodes"]), &spec()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &spec()).is_err());
        let a = parse(&sv(&["--nodes", "abc"]), &spec()).unwrap();
        assert!(a.usize_or("nodes", 0).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("simulate", "Run the simulator", &spec());
        assert!(u.contains("--nodes"));
        assert!(u.contains("default: 4"));
    }
}
