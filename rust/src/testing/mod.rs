//! Test/bench substrates built in-repo: a micro-benchmark harness
//! (criterion analog), a property-testing harness (proptest analog), and
//! shared fixtures.

pub mod bench;
pub mod fixtures;
pub mod prop;

pub use bench::{bench, BenchResult};
pub use prop::{check, PropConfig};
