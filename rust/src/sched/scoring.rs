//! Dense batched scoring — the numeric hot path of Algorithm 1 expressed
//! over padded vectors. This module defines the input/output layout shared
//! by the two backends:
//!
//! - [`NativeScorer`] (here): pure-rust reference implementation, always
//!   available, used by default and as the differential-test oracle.
//! - `runtime::XlaScorer`: executes the AOT-compiled JAX/Pallas artifact
//!   (`python/compile/model.py` lowers the *same math* to HLO).
//!
//! Layout: `present` is row-major `[n_nodes_cap × n_layers_cap]` with 0/1
//! entries; every per-node vector has length `n_nodes_cap`; `req`/`sizes_mb`
//! have length `n_layers_cap`. Capacities are the artifact's fixed shapes —
//! the native scorer accepts any size.

use super::dynamic_weight::WeightParams;

/// Scores below this are "minus infinity" for masked (infeasible) nodes.
pub const NEG_MASK: f32 = -1.0e30;

/// Dense inputs for one scheduling cycle.
#[derive(Debug, Clone)]
pub struct ScoreInputs {
    /// Logical node count (≤ row capacity).
    pub n_nodes: usize,
    /// Logical layer count (≤ column capacity).
    pub n_layers: usize,
    /// Row-major node×layer presence (1.0 where the node holds the layer).
    pub present: Vec<f32>,
    /// 1.0 where the pod's image requires the layer.
    pub req: Vec<f32>,
    /// Layer sizes in MB.
    pub sizes_mb: Vec<f32>,
    /// Per-node CPU requested (millicores, any consistent unit).
    pub cpu_used: Vec<f32>,
    /// Per-node CPU capacity.
    pub cpu_cap: Vec<f32>,
    /// Per-node memory requested.
    pub mem_used: Vec<f32>,
    /// Per-node memory capacity.
    pub mem_cap: Vec<f32>,
    /// S_K8s per node (already weighted/normalized by the framework).
    pub k8s_score: Vec<f32>,
    /// 1.0 for feasible nodes, 0.0 for filtered ones.
    pub feasible: Vec<f32>,
    /// Dynamic-weight parameters.
    pub params: WeightParams,
}

impl ScoreInputs {
    /// Zeroed inputs at the given capacity.
    pub fn zeros(n_nodes: usize, n_layers: usize, params: WeightParams) -> ScoreInputs {
        ScoreInputs {
            n_nodes,
            n_layers,
            present: vec![0.0; n_nodes * n_layers],
            req: vec![0.0; n_layers],
            sizes_mb: vec![0.0; n_layers],
            cpu_used: vec![0.0; n_nodes],
            cpu_cap: vec![1.0; n_nodes], // avoid 0/0 in padding rows
            mem_used: vec![0.0; n_nodes],
            mem_cap: vec![1.0; n_nodes],
            k8s_score: vec![0.0; n_nodes],
            feasible: vec![0.0; n_nodes],
            params,
        }
    }

    /// Flat parameter vector handed to the XLA artifact:
    /// `[ω₁, ω₂, h_size, h_cpu, h_std]`.
    pub fn params_vec(&self) -> [f32; 5] {
        [
            self.params.omega1 as f32,
            self.params.omega2 as f32,
            self.params.h_size_mb as f32,
            self.params.h_cpu as f32,
            self.params.h_std as f32,
        ]
    }
}

/// Per-node outputs of the scoring pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ScoreOutputs {
    /// Final S = ω·S_layer + S_K8s, masked to NEG_MASK where infeasible.
    pub final_score: Vec<f32>,
    /// S_layer (Eq. 3).
    pub layer_score: Vec<f32>,
    /// The ω each node was scored with (Eq. 13 gate applied).
    pub omega: Vec<f32>,
    /// Argmax over final_score (Eq. 5).
    pub best: usize,
}

/// Backend interface implemented natively and by the XLA runtime.
pub trait ScoringBackend {
    /// Backend name for reports (`native` / `xla`).
    fn name(&self) -> &'static str;
    /// Score one cycle's dense inputs.
    fn score(&mut self, inputs: &ScoreInputs) -> ScoreOutputs;
}

/// Pure-rust implementation of the L2 scoring pipeline.
#[derive(Debug, Default, Clone)]
pub struct NativeScorer;

impl ScoringBackend for NativeScorer {
    fn name(&self) -> &'static str {
        "native"
    }

    fn score(&mut self, x: &ScoreInputs) -> ScoreOutputs {
        let (n, l) = (x.n_nodes, x.n_layers);
        debug_assert_eq!(x.present.len(), n * l);
        // Required layers are sparse (a pod needs a handful of the
        // interner's layers): gather (index, weight) pairs once and reduce
        // only over them — ~5× fewer flops than the dense row product at
        // the 20%-density the workloads produce (§Perf in EXPERIMENTS.md).
        let mut req_idx: Vec<(u32, f32)> = Vec::with_capacity(l / 4);
        let mut total_mb = 0.0f32;
        for j in 0..l {
            let w = x.req[j] * x.sizes_mb[j];
            if w != 0.0 {
                req_idx.push((j as u32, w));
                total_mb += w;
            }
        }
        let p = &x.params;
        let mut final_score = vec![0.0f32; n];
        let mut layer_score = vec![0.0f32; n];
        let mut omega = vec![0.0f32; n];
        for i in 0..n {
            // shared[i] = Σ_j present[i,j]·req[j]·size[j]  (Eq. 2, in MB)
            let row = &x.present[i * l..(i + 1) * l];
            let mut shared = 0.0f32;
            for &(j, w) in &req_idx {
                shared += row[j as usize] * w;
            }
            // Eq. 3.
            let s_layer = if total_mb > 0.0 { shared / total_mb * 100.0 } else { 0.0 };
            // Eqs. 11–12.
            let cpu_frac = if x.cpu_cap[i] > 0.0 { x.cpu_used[i] / x.cpu_cap[i] } else { 0.0 };
            let mem_frac = if x.mem_cap[i] > 0.0 { x.mem_used[i] / x.mem_cap[i] } else { 0.0 };
            let s_std = (cpu_frac - mem_frac).abs() / 2.0;
            // Eq. 13 gate → ω.
            let gate = shared > p.h_size_mb as f32
                && cpu_frac < p.h_cpu as f32
                && s_std < p.h_std as f32;
            let w = if gate { p.omega1 as f32 } else { p.omega2 as f32 };
            // Eq. 4 + feasibility mask.
            let s = w * s_layer + x.k8s_score[i];
            final_score[i] = if x.feasible[i] > 0.5 { s } else { NEG_MASK };
            layer_score[i] = s_layer;
            omega[i] = w;
        }
        // Eq. 5: argmax (first max wins, matching jnp.argmax).
        let best = argmax(&final_score);
        ScoreOutputs { final_score, layer_score, omega, best }
    }
}

/// Persistent dense-input arena for the scoring hot path.
///
/// [`ScoreInputs::zeros`] rebuilds every O(N·L) buffer from scratch each
/// scheduling cycle. Between consecutive cycles almost nothing changes:
/// node presence rows only change when a node installs or evicts layers,
/// the interner only appends, and the sparse `req`/`feasible` indicators
/// touch a handful of entries. The arena keeps one `ScoreInputs` alive and
/// applies those deltas — undo lists for the sparse vectors, a per-node
/// `layers_version` check for the dense presence rows — so steady-state
/// cycles are allocation-free and O(dirty) instead of O(N·L).
///
/// Layer capacity is padded to a power of two so interner growth triggers
/// only O(log L) full reallocations. Padding columns keep `req = 0` and
/// padding rows keep `feasible = 0`, which both backends already mask.
///
/// An arena must be reused against a single evolving [`ClusterState`]
/// (`layers_version` comparisons are meaningless across states); the
/// engine guarantees this by owning one scheduler per simulation.
///
/// [`ClusterState`]: crate::cluster::ClusterState
pub struct ScoreArena {
    inputs: ScoreInputs,
    /// `layers_version` seen per node row (u64::MAX = never filled).
    node_versions: Vec<u64>,
    /// Indices set in `req` by the previous fill (sparse undo list).
    req_set: Vec<u32>,
    /// Node indices with `k8s_score`/`feasible` set by the previous fill.
    feas_set: Vec<u32>,
    /// Prefix of `sizes_mb` already written (the interner only appends).
    sizes_filled: usize,
    /// Observability: full arena reallocations (capacity growth).
    pub full_rebuilds: u64,
    /// Observability: presence rows rewritten because a node's layer set
    /// changed (or was never filled).
    pub rows_refilled: u64,
}

impl Default for ScoreArena {
    fn default() -> ScoreArena {
        ScoreArena::new()
    }
}

impl ScoreArena {
    /// An empty arena (first fill allocates).
    pub fn new() -> ScoreArena {
        ScoreArena {
            inputs: ScoreInputs::zeros(0, 0, WeightParams::default()),
            node_versions: Vec::new(),
            req_set: Vec::new(),
            feas_set: Vec::new(),
            sizes_filled: 0,
            full_rebuilds: 0,
            rows_refilled: 0,
        }
    }

    /// Bring the arena up to date for one cycle and return the inputs.
    /// Equivalent to `lrscheduler::build_inputs` (the padded entries are
    /// masked), but incremental.
    pub fn fill(
        &mut self,
        ctx: &crate::sched::context::CycleContext,
        k8s_scores: &[crate::sched::framework::NodeScore],
        params: &WeightParams,
    ) -> &ScoreInputs {
        let n = ctx.state.node_count();
        let l = ctx.state.interner.len();
        if n > self.inputs.n_nodes || l > self.inputs.n_layers {
            let n_cap = n.max(self.inputs.n_nodes);
            let l_cap = l.next_power_of_two().max(64).max(self.inputs.n_layers);
            self.inputs = ScoreInputs::zeros(n_cap, l_cap, *params);
            self.node_versions = vec![u64::MAX; n_cap];
            self.req_set.clear();
            self.feas_set.clear();
            self.sizes_filled = 0;
            self.full_rebuilds += 1;
        }
        let x = &mut self.inputs;
        x.params = *params;
        let lcap = x.n_layers;

        // Layer sizes: the interner is append-only, so extend the prefix.
        for i in self.sizes_filled..l {
            x.sizes_mb[i] =
                ctx.state.interner.size(crate::registry::LayerId(i as u32)).as_mb() as f32;
        }
        self.sizes_filled = self.sizes_filled.max(l);

        // Required-layer indicator: undo the previous cycle, set this one.
        for &j in &self.req_set {
            x.req[j as usize] = 0.0;
        }
        self.req_set.clear();
        for id in ctx.required_layers.iter() {
            x.req[id.0 as usize] = 1.0;
            self.req_set.push(id.0);
        }

        // Presence rows: rewrite only nodes whose layer set changed.
        for (i, node) in ctx.state.nodes().iter().enumerate() {
            if self.node_versions[i] != node.layers_version {
                let row = &mut x.present[i * lcap..(i + 1) * lcap];
                row.fill(0.0);
                node.layers.write_indicator(row);
                self.node_versions[i] = node.layers_version;
                self.rows_refilled += 1;
            }
            x.cpu_used[i] = node.used.cpu.0 as f32;
            x.cpu_cap[i] = node.capacity.cpu.0.max(1) as f32;
            x.mem_used[i] = node.used.memory.0 as f32;
            x.mem_cap[i] = node.capacity.memory.0.max(1) as f32;
        }

        // Feasibility + S_K8s: undo the previous cycle, set this one.
        for &i in &self.feas_set {
            x.k8s_score[i as usize] = 0.0;
            x.feasible[i as usize] = 0.0;
        }
        self.feas_set.clear();
        for ns in k8s_scores {
            x.k8s_score[ns.node.0 as usize] = ns.total as f32;
            x.feasible[ns.node.0 as usize] = 1.0;
            self.feas_set.push(ns.node.0);
        }
        &self.inputs
    }
}

/// First-index argmax, matching `jnp.argmax` semantics for ties.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs_2x4() -> ScoreInputs {
        let mut x = ScoreInputs::zeros(2, 4, WeightParams::default());
        // Layers: sizes 10, 20, 30, 40 MB; pod requires layers 0,1,3 (70 MB).
        x.sizes_mb = vec![10.0, 20.0, 30.0, 40.0];
        x.req = vec![1.0, 1.0, 0.0, 1.0];
        // Node 0 holds layers 1,2 → shared 20 MB; node 1 holds nothing.
        x.present[0 * 4 + 1] = 1.0;
        x.present[0 * 4 + 2] = 1.0;
        x.cpu_used = vec![1.0, 1.0];
        x.cpu_cap = vec![4.0, 4.0];
        x.mem_used = vec![1.0, 1.0];
        x.mem_cap = vec![4.0, 4.0];
        x.k8s_score = vec![50.0, 60.0];
        x.feasible = vec![1.0, 1.0];
        x
    }

    #[test]
    fn native_scorer_matches_hand_math() {
        let x = inputs_2x4();
        let out = NativeScorer.score(&x);
        // Node 0: shared 20/70 → layer 28.571…; idle & balanced & >10MB → ω=2.
        let expected_layer0 = 20.0 / 70.0 * 100.0;
        assert!((out.layer_score[0] - expected_layer0).abs() < 1e-4);
        assert_eq!(out.omega[0], 2.0);
        assert!((out.final_score[0] - (2.0 * expected_layer0 + 50.0)).abs() < 1e-4);
        // Node 1: shared 0 → gate fails (h_size) → ω=0.5, final = 60.
        assert_eq!(out.omega[1], 0.5);
        assert!((out.final_score[1] - 60.0).abs() < 1e-4);
        // Node 0 wins: 107.1 > 60.
        assert_eq!(out.best, 0);
    }

    #[test]
    fn infeasible_nodes_masked() {
        let mut x = inputs_2x4();
        x.feasible = vec![0.0, 1.0];
        let out = NativeScorer.score(&x);
        assert_eq!(out.final_score[0], NEG_MASK);
        assert_eq!(out.best, 1);
    }

    #[test]
    fn gate_respects_cpu_threshold() {
        let mut x = inputs_2x4();
        x.cpu_used = vec![3.0, 1.0]; // node 0 at 75% ≥ h_cpu=0.6
        x.mem_used = vec![3.0, 1.0];
        let out = NativeScorer.score(&x);
        assert_eq!(out.omega[0], 0.5);
    }

    #[test]
    fn gate_respects_std_threshold() {
        let mut x = inputs_2x4();
        x.cpu_used = vec![2.0, 1.0]; // cpu 50%, mem 25% → std 0.125 < 0.16 passes
        x.mem_used = vec![1.0, 1.0];
        assert_eq!(NativeScorer.score(&x).omega[0], 2.0);
        x.mem_used = vec![0.0, 1.0]; // cpu 50%, mem 0% → std 0.25 ≥ 0.16 fails
        assert_eq!(NativeScorer.score(&x).omega[0], 0.5);
    }

    #[test]
    fn zero_required_bytes_zero_layer_score() {
        let mut x = inputs_2x4();
        x.req = vec![0.0; 4];
        let out = NativeScorer.score(&x);
        assert_eq!(out.layer_score, vec![0.0, 0.0]);
        assert_eq!(out.best, 1); // falls back to k8s score
    }

    #[test]
    fn argmax_first_tie_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }

    mod arena {
        use super::super::*;
        use crate::cluster::{NodeId, PodBuilder, Resources};
        use crate::registry::hub;
        use crate::sched::context::CycleContext;
        use crate::sched::lrscheduler::build_inputs;
        use crate::sched::profiles::default_framework;
        use crate::testing::fixtures;

        /// Outputs of a fresh zeros-rebuild and the arena must agree on
        /// every real node and on the winner. `state` is the single
        /// evolving cluster the arena tracks (its interner only appends).
        fn assert_agree(
            state: &mut crate::cluster::ClusterState,
            cache: &crate::registry::MetadataCache,
            arena: &mut ScoreArena,
            image: &str,
            tag: &str,
        ) {
            let pod = PodBuilder::new().build(
                &format!("{image}:{tag}"),
                Resources::cores_gb(0.25, 0.25),
            );
            let (meta, req, bytes) = CycleContext::prepare(state, cache, &pod);
            let ctx = CycleContext::new(state, &pod, meta, req, bytes);
            let fw = default_framework();
            let feasible = fw.feasible(&ctx).expect("feasible nodes");
            let scores = fw.score(&ctx, &feasible);
            let params = WeightParams::default();

            let fresh = build_inputs(&ctx, &scores, &params);
            let out_fresh = NativeScorer.score(&fresh);
            let reused = arena.fill(&ctx, &scores, &params);
            let out_arena = NativeScorer.score(reused);

            let n = ctx.state.node_count();
            for i in 0..n {
                assert_eq!(out_fresh.omega[i], out_arena.omega[i], "omega[{i}]");
                assert!(
                    (out_fresh.layer_score[i] - out_arena.layer_score[i]).abs() < 1e-4,
                    "layer[{i}]: {} vs {}",
                    out_fresh.layer_score[i],
                    out_arena.layer_score[i]
                );
                assert!(
                    (out_fresh.final_score[i] - out_arena.final_score[i]).abs() < 1e-3,
                    "final[{i}]"
                );
            }
            assert_eq!(out_fresh.best, out_arena.best, "winner differs");
        }

        #[test]
        fn arena_matches_zeros_rebuild_across_mutations() {
            let mut state = fixtures::uniform_cluster(4);
            let cache = fixtures::corpus_cache();
            let mut arena = ScoreArena::new();
            // Cold cluster.
            assert_agree(&mut state, &cache, &mut arena, "redis", "7.2");
            assert_eq!(arena.full_rebuilds, 1);

            // Install an image → its node's presence row goes dirty.
            let corpus = hub::corpus();
            let wp = corpus.iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
            let (_, layers) = state.intern_image(wp);
            state.install_image(NodeId(1), &wp.image_ref(), &layers).unwrap();
            assert_agree(&mut state, &cache, &mut arena, "wordpress", "6.4");

            // Evict part of the image → dirty again, bits must clear.
            let ids: Vec<_> = layers.iter().collect();
            state.evict_layers(NodeId(1), &ids);
            state.remove_image(NodeId(1), &wp.image_ref());
            assert_agree(&mut state, &cache, &mut arena, "wordpress", "6.4");

            // A different pod image only flips the sparse req indicator.
            assert_agree(&mut state, &cache, &mut arena, "nginx", "1.25");
        }

        #[test]
        fn arena_skips_clean_rows() {
            let mut state = fixtures::uniform_cluster(3);
            let cache = fixtures::corpus_cache();
            let mut arena = ScoreArena::new();
            assert_agree(&mut state, &cache, &mut arena, "redis", "7.2");
            let rows_after_first = arena.rows_refilled;
            assert_eq!(rows_after_first, 3, "all rows filled once");
            // Same cluster state: no presence row should be rewritten.
            assert_agree(&mut state, &cache, &mut arena, "nginx", "1.25");
            assert_agree(&mut state, &cache, &mut arena, "redis", "7.2");
            assert_eq!(arena.rows_refilled, rows_after_first);
            assert_eq!(arena.full_rebuilds, 1);
        }
    }

    #[test]
    fn padding_rows_never_win() {
        // Capacity 8 nodes, only 2 real: padding has feasible=0.
        let mut x = ScoreInputs::zeros(8, 4, WeightParams::default());
        x.feasible[0] = 1.0;
        x.feasible[1] = 1.0;
        x.k8s_score[0] = 10.0;
        x.k8s_score[1] = 20.0;
        let out = NativeScorer.score(&x);
        assert_eq!(out.best, 1);
        for i in 2..8 {
            assert_eq!(out.final_score[i], NEG_MASK);
        }
    }
}
