//! ImageLocality — "prefers nodes with the container images already
//! present" (paper §IV-B). Scores follow the upstream formula: the image's
//! size is scaled by the fraction of nodes that already hold it (to avoid
//! node heating), then mapped through fixed thresholds to 0–100.
//!
//! Note the contrast that motivates the paper: ImageLocality is *whole-
//! image* locality — a node holding 5 of 6 layers scores zero. The
//! layer-aware score (Eq. 3) is the refinement.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{ScorePlugin, MAX_NODE_SCORE};
use crate::util::units::Bytes;

/// Upstream thresholds (`pkg/scheduler/framework/plugins/imagelocality`).
const MIN_THRESHOLD: f64 = 23.0 * 1024.0 * 1024.0; // 23 MiB
const MAX_THRESHOLD: f64 = 1000.0 * 1024.0 * 1024.0; // 1000 MiB

/// ImageLocality: favor nodes that already hold (part of) the image.
pub struct ImageLocality;

impl ImageLocality {
    /// Upstream `scaledImageScore`: image size × spread fraction.
    fn scaled_image_score(size: Bytes, nodes_with_image: usize, total_nodes: usize) -> f64 {
        if total_nodes == 0 {
            return 0.0;
        }
        size.0 as f64 * (nodes_with_image as f64 / total_nodes as f64)
    }
}

impl ScorePlugin for ImageLocality {
    fn name(&self) -> &'static str {
        "ImageLocality"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        if !node.has_image(&ctx.pod.image) {
            return 0.0;
        }
        let total_nodes = ctx.state.node_count();
        let nodes_with = ctx
            .state
            .nodes()
            .iter()
            .filter(|n| n.has_image(&ctx.pod.image))
            .count();
        let sum_scores = Self::scaled_image_score(ctx.required_bytes, nodes_with, total_nodes);
        if sum_scores < MIN_THRESHOLD {
            0.0
        } else if sum_scores > MAX_THRESHOLD {
            MAX_NODE_SCORE
        } else {
            MAX_NODE_SCORE * (sum_scores - MIN_THRESHOLD) / (MAX_THRESHOLD - MIN_THRESHOLD)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::hub;
    use crate::util::units::Bandwidth;

    fn setup() -> ClusterState {
        let mut s = ClusterState::new();
        for i in 0..4 {
            s.add_node(Node::new(
                NodeId(i),
                &format!("n{i}"),
                Resources::cores_gb(4.0, 4.0),
                Bytes::from_gb(30.0),
                Bandwidth::from_mbps(10.0),
            ));
        }
        s
    }

    #[test]
    fn node_without_image_scores_zero() {
        let mut state = setup();
        let corpus = hub::corpus();
        let ghost = corpus.iter().find(|m| m.name == "ghost").unwrap();
        let (_, layers) = state.intern_image(ghost);
        state.install_image(NodeId(0), &ghost.image_ref(), &layers).unwrap();

        let pod = PodBuilder::new().build("ghost:5", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(ghost), layers, ghost.total_size);
        let s_with = ImageLocality.score(&ctx, state.node(NodeId(0)));
        let s_without = ImageLocality.score(&ctx, state.node(NodeId(1)));
        assert!(s_with > 0.0);
        assert_eq!(s_without, 0.0);
    }

    #[test]
    fn small_image_below_threshold_scores_zero() {
        let mut state = setup();
        let corpus = hub::corpus();
        let alpine = corpus.iter().find(|m| m.name == "alpine").unwrap(); // 3.4 MB
        let (_, layers) = state.intern_image(alpine);
        state.install_image(NodeId(0), &alpine.image_ref(), &layers).unwrap();
        let pod = PodBuilder::new().build("alpine:3.19", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(alpine), layers, alpine.total_size);
        assert_eq!(ImageLocality.score(&ctx, state.node(NodeId(0))), 0.0);
    }

    #[test]
    fn wider_spread_raises_score() {
        let mut state = setup();
        let corpus = hub::corpus();
        let gcc = corpus.iter().find(|m| m.name == "gcc").unwrap(); // ~824 MB
        let (_, layers) = state.intern_image(gcc);
        state.install_image(NodeId(0), &gcc.image_ref(), &layers).unwrap();
        let pod = PodBuilder::new().build("gcc:13", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, Some(gcc), layers.clone(), gcc.total_size);
        let one_holder = ImageLocality.score(&ctx, state.node(NodeId(0)));

        state.install_image(NodeId(1), &gcc.image_ref(), &layers).unwrap();
        let ctx2 = CycleContext::new(&state, &pod, Some(gcc), layers, gcc.total_size);
        let two_holders = ImageLocality.score(&ctx2, state.node(NodeId(0)));
        assert!(two_holders > one_holder, "{two_holders} <= {one_holder}");
    }
}
