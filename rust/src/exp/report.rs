//! Plain-text table and series printers for the experiment drivers —
//! the output mirrors the rows/series of the paper's figures and tables.

/// Render a table: header row + data rows, column-aligned.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}", w = w))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let headers_owned: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&headers_owned, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Render a labelled numeric series, one `label: v1 v2 …` per line.
pub fn series(title: &str, lines: &[(String, Vec<f64>)], precision: usize) -> String {
    let mut out = format!("{title}\n");
    let label_w = lines.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, values) in lines {
        let vals = values
            .iter()
            .map(|v| format!("{v:.precision$}"))
            .collect::<Vec<_>>()
            .join(" ");
        out.push_str(&format!("{label:>label_w$}: {vals}\n"));
    }
    out
}

/// Format with 1 decimal place.
pub fn f1(v: f64) -> String {
    format!("{v:.1}")
}

/// Format with 3 decimal places.
pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns() {
        let t = table(
            &["name", "mb"],
            &[
                vec!["a".into(), "1.0".into()],
                vec!["longer".into(), "12345.6".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[3].contains("12345.6"));
        // Columns aligned: both data lines same length.
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn series_formats() {
        let s = series(
            "downloads",
            &[("Default".to_string(), vec![1.0, 2.5]), ("LR".to_string(), vec![0.5, 0.25])],
            2,
        );
        assert!(s.contains("downloads"));
        assert!(s.contains("Default: 1.00 2.50"));
        assert!(s.contains("     LR: 0.50 0.25"));
    }
}
