//! TaintToleration — "implements taints and tolerations, reducing
//! deployment priority for tainted nodes" (paper §IV-B).
//!
//! Hard (NoSchedule) taints filter; soft (PreferNoSchedule) taints count
//! against the node in scoring, normalized so the node with the most
//! intolerable soft taints scores 0 (upstream behaviour).

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{
    normalize_inverse, FilterPlugin, FilterResult, ScorePlugin,
};

/// TaintToleration filter: hard (NoSchedule) taints require a matching
/// toleration.
pub struct TaintTolerationFilter;

impl FilterPlugin for TaintTolerationFilter {
    fn name(&self) -> &'static str {
        "TaintToleration"
    }

    fn filter(&self, ctx: &CycleContext, node: &Node) -> FilterResult {
        for taint in node.taints.iter().filter(|t| t.hard) {
            if !ctx.pod.tolerates(&taint.key, &taint.value) {
                return FilterResult::Reject(format!(
                    "untolerated taint {}={}",
                    taint.key, taint.value
                ));
            }
        }
        FilterResult::Pass
    }
}

/// TaintToleration score: soft (PreferNoSchedule) taints lower the
/// score unless tolerated.
pub struct TaintTolerationScore;

impl ScorePlugin for TaintTolerationScore {
    fn name(&self) -> &'static str {
        "TaintToleration"
    }

    /// Raw score = count of intolerable soft taints (badness).
    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        node.taints
            .iter()
            .filter(|t| !t.hard && !ctx.pod.tolerates(&t.key, &t.value))
            .count() as f64
    }

    fn normalize(&self, _ctx: &CycleContext, scores: &mut [f64]) {
        normalize_inverse(scores);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    fn node(id: u32) -> Node {
        Node::new(
            NodeId(id),
            &format!("n{id}"),
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(20.0),
            Bandwidth::from_mbps(10.0),
        )
    }

    #[test]
    fn hard_taint_filters_unless_tolerated() {
        let state = ClusterState::new();
        let mut b = PodBuilder::new();
        let plain = b.build("redis", Resources::ZERO);
        let tolerant = b.build("redis", Resources::ZERO).with_toleration("gpu", "only");
        let tainted = node(0).with_taint("gpu", "only", true);

        let ctx = CycleContext::new(&state, &plain, None, LayerSet::new(), Bytes::ZERO);
        assert!(matches!(
            TaintTolerationFilter.filter(&ctx, &tainted),
            FilterResult::Reject(_)
        ));
        let ctx2 = CycleContext::new(&state, &tolerant, None, LayerSet::new(), Bytes::ZERO);
        assert_eq!(TaintTolerationFilter.filter(&ctx2, &tainted), FilterResult::Pass);
    }

    #[test]
    fn soft_taints_lower_score() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let clean = node(0);
        let soft = node(1).with_taint("edge", "flaky", false);
        let mut scores = vec![
            TaintTolerationScore.score(&ctx, &clean),
            TaintTolerationScore.score(&ctx, &soft),
        ];
        TaintTolerationScore.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![100.0, 0.0]);
    }

    #[test]
    fn soft_taint_does_not_filter() {
        let state = ClusterState::new();
        let pod = PodBuilder::new().build("redis", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let soft = node(0).with_taint("edge", "flaky", false);
        assert_eq!(TaintTolerationFilter.filter(&ctx, &soft), FilterResult::Pass);
    }
}
