//! `lrsched` — CLI entrypoint. Subcommands drive the simulator and the
//! experiment harnesses that regenerate every figure/table of the paper's
//! evaluation, plus registry inspection and a one-shot scoring tool.

use lrsched::cli::{self, specs, OptSpec};
use lrsched::exp::{common, fig3, fig4, fig5, table1};
use lrsched::registry::Registry;
use lrsched::runtime::XlaScorer;
use lrsched::sim::{SchedulerChoice, SimConfig, Simulation, WorkloadConfig, WorkloadGen};
use lrsched::util::logging;

const ABOUT: &str = "lrsched — layer-aware, resource-adaptive container scheduler \
(LRScheduler reproduction)

Subcommands:
  simulate   run a workload trace through a scheduler on the paper testbed
  scale      drive a 100k-pod timed trace through the event engine; add
             --churn for node joins/drains/crashes + a registry outage
             window (e.g. `lrsched scale --churn --churn-crash-frac 0.05`),
             or replay a real cluster trace with --trace <csv>
             --trace-format {alibaba,azure} (see docs/SCALE.md)
  serve      online decision service: pod/node lifecycle events as NDJSON
             over stdin (or --listen <addr> for HTTP) in, one binding
             decision per pod out; --shadow <csv> replays a trace through
             the serve path and verifies byte-identity with `scale
             --trace` (see docs/SERVE.md)
  gen-trace  write a synthetic Alibaba-dialect trace CSV (or .csv.gz) for
             streaming-ingest benchmarks and the CI bounded-memory gate
  fig3       regenerate Fig. 3 (a-f): performance vs node count
  fig4       regenerate Fig. 4: download time vs bandwidth
  fig5       regenerate Fig. 5: accumulated download size
  table1     regenerate Table I: per-container size/time/STD
  export     write figure/table data as JSON/CSV for external plotting
  registry   show the synthetic registry catalog and layer sharing
  lint       statically check the crate source against the determinism
             contract (R1-R4; see docs/ARCHITECTURE.md)
  help       this text (or `help <subcommand>`)";

/// `lint`: walk the crate source and enforce the determinism contract
/// (R1 hash-order escape, R2 ambient nondeterminism, R3 unsafe hygiene,
/// R4 pool-closure accumulation). Exit 2 with `file:line` diagnostics on
/// any violation or stale suppression.
fn run_lint(rest: &[String]) -> Result<(), String> {
    let args = cli::parse(rest, &specs::lint())?;
    apply_log_level(&args)?;
    if args.flag("self-test") {
        lrsched::lint::self_test()?;
        println!("lint self-test: every rule fixture trips exactly as pinned");
        return Ok(());
    }
    let root = match args.get("root") {
        Some(r) if !r.is_empty() => std::path::PathBuf::from(r),
        // Resolve the crate source whether invoked from the repo root or
        // from inside rust/.
        _ if std::path::Path::new("rust/src").is_dir() => std::path::PathBuf::from("rust/src"),
        _ => std::path::PathBuf::from("src"),
    };
    let report = lrsched::lint::run(&root)?;
    if args.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        for d in &report.diagnostics {
            println!("{d}");
        }
    }
    if !report.clean() {
        return Err(format!(
            "lint: {} violation(s) across {} files",
            report.diagnostics.len(),
            report.files
        ));
    }
    if !args.flag("json") {
        println!("lint: {} files clean under the determinism contract (R1-R4)", report.files);
    }
    Ok(())
}

/// `gen-trace`: deterministically generate a synthetic Alibaba-dialect
/// trace — the input for `scale --trace` streaming-ingest benchmarks and
/// the CI bounded-memory gate.
fn run_gen_trace(rest: &[String]) -> Result<(), String> {
    let args = cli::parse(rest, &specs::gen_trace())?;
    apply_log_level(&args)?;
    let rows = args.usize_or("rows", 1_000_000)?;
    let seed = args.u64_or("seed", 42)?;
    let out = args
        .get("out")
        .ok_or_else(|| "--out is required (e.g. --out big.csv.gz)".to_string())?
        .to_string();
    let csv = lrsched::testing::fixtures::synthetic_alibaba_csv(rows, seed);
    let bytes: Vec<u8> = if out.ends_with(".gz") {
        lrsched::util::gzip::compress_stored(csv.as_bytes())
    } else {
        csv.into_bytes()
    };
    std::fs::write(&out, &bytes).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {rows} Alibaba-dialect rows ({} bytes) to {out}", bytes.len());
    Ok(())
}

fn run_scale(rest: &[String]) -> Result<(), String> {
    use lrsched::sched::NativeScorer;
    use lrsched::sim::{
        ArrivalSource, ErrorMode, Popularity, TraceErrorSlot, TraceFormat, TraceOptions,
        TraceReplay, WorkloadSource,
    };

    let args = cli::parse(rest, &specs::scale())?;
    apply_log_level(&args)?;
    let seed = args.u64_or("seed", 42)?;
    let pods = args.usize_or("pods", 100_000)?;
    let nodes = args.usize_or("nodes", 64)?;
    let arrival = args.f64_or("arrival", 0.3)?;
    let dmin = args.f64_or("duration-min", 30.0)?;
    let dmax = args.f64_or("duration-max", 300.0)?;
    let zipf = args.f64_or("zipf", 1.1)?;
    let scheduler = match args.str_or("scheduler", "lr") {
        "default" => SchedulerChoice::Default,
        "layer" => SchedulerChoice::Layer,
        "lr" => SchedulerChoice::LR,
        "rl" => SchedulerChoice::Rl,
        other => return Err(format!("unknown scheduler {other:?}")),
    };

    // Workload: a real trace replay (--trace) or the synthetic Zipf
    // generator. Both are pull-based ArrivalSources: the engine holds one
    // future arrival at a time, so ingestion memory does not grow with
    // the workload length.
    let mut trace_error_slot: Option<TraceErrorSlot> = None;
    let (registry, source, n_pods, horizon, trace_note): (
        Registry,
        Box<dyn ArrivalSource>,
        usize,
        f64,
        Option<String>,
    ) = match args.get("trace") {
        Some(path) => {
            let fmt_name = args.str_or("trace-format", "alibaba");
            let format = TraceFormat::parse(fmt_name).ok_or_else(|| {
                format!("unknown trace format {fmt_name:?} (expected alibaba|azure|borg)")
            })?;
            let speedup = args.f64_or("trace-speedup", 1.0)?;
            if speedup <= 0.0 {
                return Err("--trace-speedup must be positive".to_string());
            }
            let limit = args.usize_or("trace-limit", 0)?;
            let opts = TraceOptions {
                format,
                mode: if args.flag("trace-strict") { ErrorMode::Strict } else { ErrorMode::Lenient },
                speedup,
                limit: if limit == 0 { None } else { Some(limit) },
                seed,
                reorder_cap: args.usize_or("trace-reorder", 65_536)?.max(1),
            };
            let replay =
                TraceReplay::open(std::path::Path::new(path), &opts).map_err(|e| e.to_string())?;
            let registry = replay.synthesize_registry();
            let s = replay.stats.clone();
            let note = format!(
                "trace: {path} format={} events={} apps={} span={:.1}s speedup={speedup:.0}x \
                 skipped={} duplicates={} filtered={} reorder_depth={} ingest={}{}{}{}",
                format.label(),
                s.events,
                s.apps,
                s.span_secs,
                s.skipped,
                s.duplicates,
                s.filtered,
                s.reorder_depth,
                s.ingest_path.label(),
                if s.resorted { " (reordered)" } else { "" },
                if s.full_resort { " (full-sort fallback)" } else { "" },
                if s.limit_hit {
                    format!(" (limit hit, +{} truncated)", s.truncated_events)
                } else {
                    String::new()
                },
            );
            let events = s.events;
            let source = replay.into_source();
            trace_error_slot = Some(source.error_slot());
            (
                registry,
                Box::new(source) as Box<dyn ArrivalSource>,
                events,
                s.span_secs.max(60.0),
                Some(note),
            )
        }
        None => {
            let registry = Registry::with_corpus();
            let wl = lrsched::sim::WorkloadConfig {
                seed,
                popularity: if zipf > 0.0 { Popularity::Zipf(zipf) } else { Popularity::Uniform },
                duration_range: if dmax > 0.0 { Some((dmin, dmax.max(dmin))) } else { None },
                ..Default::default()
            };
            let dt = arrival.max(1e-6);
            // Lazy: pods are generated as the engine pulls them.
            let source = WorkloadSource::new(WorkloadGen::new(&registry, wl), dt, pods);
            (
                registry,
                Box::new(source) as Box<dyn ArrivalSource>,
                pods,
                (pods as f64 * dt).max(60.0),
                None,
            )
        }
    };

    let mut cfg = SimConfig::default();
    cfg.scheduler = scheduler;
    cfg.inter_arrival_secs = Some(arrival.max(1e-6));
    cfg.gc_enabled = !args.flag("no-gc");
    cfg.retry_limit = args.get_parsed::<u32>("retry-limit")?.unwrap_or(10);
    cfg.retry_backoff_secs = args.f64_or("backoff", 5.0)?;
    cfg.snapshot_every = args.usize_or("snapshot-every", 1000)?.max(1);
    cfg.wake_on_capacity = !args.flag("no-wake");
    cfg.shards = args.usize_or("shards", 1)?.max(1);
    let policy_name = args.str_or("cache-policy", "pressure");
    cfg.cache_policy = lrsched::sim::CachePolicyChoice::parse(policy_name).ok_or_else(|| {
        format!("unknown cache policy {policy_name:?} (expected pressure|lru|popularity|scorer|prefetch)")
    })?;
    cfg.cache_decay_secs = args.f64_or("cache-decay", 300.0)?;
    cfg.cache_prefetch_bytes =
        lrsched::util::units::Bytes::from_mb(args.f64_or("cache-prefetch-mb", 256.0)?);
    if args.flag("p2p") {
        cfg.p2p_lan_mbps = Some(args.f64_or("p2p-lan", 125.0)?);
        cfg.p2p_seeder_cap = args.usize_or("p2p-seeder-cap", 4)?.max(1);
    }
    if args.flag("churn") {
        // Spread volatility across the arrival window of the whole trace.
        cfg.churn = Some(lrsched::sim::ChurnConfig {
            seed: args.u64_or("churn-seed", seed)?,
            horizon_secs: horizon,
            joins: args.usize_or("churn-joins", 3)?,
            drains: args.usize_or("churn-drains", 2)?,
            crash_fraction: args.f64_or("churn-crash-frac", 0.05)?,
            outages: args.usize_or("churn-outages", 1)?,
            outage_secs: args.f64_or("churn-outage-secs", 60.0)?,
            ..Default::default()
        });
    }

    let churn_enabled = cfg.churn.is_some();
    let p2p_cap = cfg.p2p_lan_mbps.map(|_| cfg.p2p_seeder_cap);
    let shards = cfg.shards;
    let cache_policy = cfg.cache_policy;
    let disk_gb = args.f64_or("disk-gb", 64.0)?;
    if disk_gb <= 0.0 {
        return Err("--disk-gb must be positive".to_string());
    }
    let mut sim = Simulation::new(common::scale_nodes_with_disk(nodes, disk_gb), registry, cfg);
    let backend = args.str_or("backend", "native");
    match backend {
        "native" => {}
        "dense" => {
            // The dense path exercises the persistent ScoreArena hot path.
            sim = sim.with_backend(Box::new(NativeScorer));
        }
        other => return Err(format!("unknown backend {other:?} (expected native|dense)")),
    }
    let wall = std::time::Instant::now();
    let report = sim.run_source(source);
    let wall = wall.elapsed().as_secs_f64();
    sim.state.check_invariants().map_err(|e| format!("invariant violated: {e}"))?;
    if report.submitted != n_pods {
        // A streaming source that hits an I/O or parse error mid-replay
        // ends the stream early; surface the recorded error if there is
        // one, and make the count mismatch loud either way.
        let detail = trace_error_slot
            .as_ref()
            .and_then(|slot| slot.lock().ok().and_then(|mut e| e.take()))
            .map(|e| format!(": {e}"))
            .unwrap_or_else(|| " (was the trace file modified mid-replay?)".to_string());
        return Err(format!(
            "arrival stream ended early: submitted {} of {} expected pods{detail}",
            report.submitted, n_pods
        ));
    }

    if let Some(note) = &trace_note {
        println!("{note}");
    }
    println!(
        "scale: {} pods / {} nodes / scheduler={} backend={} shards={}",
        n_pods,
        nodes,
        report.scheduler,
        backend,
        shards,
    );
    println!(
        "submitted={} completed={} failed_pulls={} unschedulable={} lost_to_crash={} retries={}",
        report.submitted,
        report.completed(),
        report.failed_pulls,
        report.unschedulable,
        report.lost_to_crash,
        report.retries
    );
    if churn_enabled {
        println!(
            "churn: joined={} drained={} crashed={} resubmitted={} pulls_stalled={} wakeups={} \
             end-of-run schedulable nodes={}/{}",
            report.nodes_joined,
            report.nodes_drained,
            report.nodes_crashed,
            report.resubmitted,
            report.pulls_stalled,
            report.wakeups,
            sim.state.schedulable_node_count(),
            sim.state.node_count()
        );
    }
    println!(
        "events queued={} virtual time={:.1}s wall={:.2}s throughput={:.0} pods/s",
        sim.events_queued(),
        sim.clock.now(),
        wall,
        n_pods as f64 / wall.max(1e-9)
    );
    println!(
        "download total={:.1} GB final_std={:.4} snapshots={}",
        report.total_download().as_gb(),
        report.final_std(),
        report.snapshots.len()
    );
    if let Some(cap) = p2p_cap {
        println!(
            "p2p: peer total={:.1} GB peak seeder uploads={} (cap {})",
            report.total_p2p().as_gb(),
            report.peak_peer_uploads,
            cap
        );
    }
    println!(
        "cache: policy={} hit_rate={:.3} evicted={:.1} MB prefetched={:.1} MB",
        cache_policy.label(),
        report.cache_hit_rate,
        report.evicted_bytes.as_mb(),
        report.prefetched_bytes.as_mb()
    );
    if !report.accounting_balanced() {
        return Err(format!(
            "dropped events: completed {} + failed {} + unschedulable {} + lost {} != submitted {}",
            report.completed(),
            report.failed_pulls,
            report.unschedulable,
            report.lost_to_crash,
            report.submitted
        ));
    }
    println!("accounting balanced: no dropped events");
    if let Some(path) = args.get("report-out") {
        std::fs::write(path, report.render()).map_err(|e| format!("{path}: {e}"))?;
        println!("wrote report fingerprint to {path}");
    }
    if let Some(path) = args.get("events-out") {
        std::fs::write(path, sim.events.render()).map_err(|e| format!("{path}: {e}"))?;
        println!("wrote event log to {path}");
    }
    Ok(())
}

/// `serve`: the online decision service (`docs/SERVE.md`). Reads NDJSON
/// pod/node lifecycle events from stdin (or HTTP with `--listen`),
/// writes one NDJSON binding decision per pod to stdout and diagnostics
/// to stderr; `--shadow <trace>` replays a trace through the serve path
/// and verifies the decision stream is byte-identical to the batch
/// `scale --trace` replay.
fn run_serve(rest: &[String]) -> Result<(), String> {
    use lrsched::serve::{run_http, run_shadow, Session};
    use lrsched::sim::{ErrorMode, TraceFormat, TraceOptions};
    use std::io::{BufRead, Write};

    let args = cli::parse(rest, &specs::serve())?;
    apply_log_level(&args)?;
    let nodes = args.usize_or("nodes", 8)?;
    if nodes == 0 {
        return Err("--nodes must be positive".to_string());
    }
    let disk_gb = args.f64_or("disk-gb", 64.0)?;
    if disk_gb <= 0.0 {
        return Err("--disk-gb must be positive".to_string());
    }
    let scheduler = match args.str_or("scheduler", "lr") {
        "default" => SchedulerChoice::Default,
        "layer" => SchedulerChoice::Layer,
        "lr" => SchedulerChoice::LR,
        "rl" => SchedulerChoice::Rl,
        other => return Err(format!("unknown scheduler {other:?}")),
    };
    let mode = if args.flag("strict") { ErrorMode::Strict } else { ErrorMode::Lenient };

    // The engine config matches `scale --trace`'s defaults exactly —
    // that equality is what makes --shadow's byte-identity check (and
    // the CI golden diff) meaningful. Timed-arrival protocol, snapshot
    // cadence 1000, single event lane.
    let mut cfg = SimConfig::default();
    cfg.scheduler = scheduler;
    cfg.inter_arrival_secs = Some(0.3);
    cfg.gc_enabled = !args.flag("no-gc");
    cfg.retry_limit = args.get_parsed::<u32>("retry-limit")?.unwrap_or(10);
    cfg.retry_backoff_secs = args.f64_or("backoff", 5.0)?;
    cfg.snapshot_every = 1000;

    if let Some(path) = args.get("shadow") {
        let fmt_name = args.str_or("trace-format", "alibaba");
        let format = TraceFormat::parse(fmt_name).ok_or_else(|| {
            format!("unknown trace format {fmt_name:?} (expected alibaba|azure|borg)")
        })?;
        let speedup = args.f64_or("trace-speedup", 1.0)?;
        if speedup <= 0.0 {
            return Err("--trace-speedup must be positive".to_string());
        }
        let limit = args.usize_or("trace-limit", 0)?;
        let opts = TraceOptions {
            format,
            mode,
            speedup,
            limit: if limit == 0 { None } else { Some(limit) },
            seed: args.u64_or("seed", 42)?,
            reorder_cap: 65_536,
        };
        let lines = run_shadow(std::path::Path::new(path), &opts, nodes, disk_gb, &cfg)?;
        let stdout = std::io::stdout();
        let mut w = stdout.lock();
        for line in &lines {
            writeln!(w, "{line}").map_err(|e| e.to_string())?;
        }
        w.flush().map_err(|e| e.to_string())?;
        eprintln!(
            "shadow: {} decision(s) byte-identical to the batch `scale --trace` replay",
            lines.len().saturating_sub(1)
        );
        return Ok(());
    }

    let mut sim =
        Simulation::new(common::scale_nodes_with_disk(nodes, disk_gb), Registry::with_corpus(), cfg);
    let wall = std::time::Instant::now();
    let mut session =
        Session::new(&mut sim, mode, Box::new(move || wall.elapsed().as_micros() as u64));

    if let Some(addr) = args.get("listen") {
        let summary = run_http(addr, &mut session)?;
        println!("{summary}");
        return Ok(());
    }

    // stdin session: one event per line in, decisions to stdout as they
    // happen, diagnostics to stderr. EOF (or a shutdown event) drains
    // the engine and emits the summary line.
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut w = stdout.lock();
    let mut lineno = 0usize;
    let mut shutdown = false;
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| format!("stdin: {e}"))?;
        lineno += 1;
        let mut out = Vec::new();
        let mut diag = Vec::new();
        let done = session.handle_line(&line, lineno, &mut out, &mut diag).map_err(|e| {
            format!("protocol error: {e} (lenient mode would skip and count it)")
        })?;
        for d in &out {
            writeln!(w, "{d}").map_err(|e| e.to_string())?;
        }
        if !out.is_empty() {
            w.flush().map_err(|e| e.to_string())?;
        }
        for d in &diag {
            eprintln!("{d}");
        }
        if done {
            shutdown = true;
            break;
        }
    }
    let mut tail = Vec::new();
    session.finish(&mut tail);
    for d in &tail {
        writeln!(w, "{d}").map_err(|e| e.to_string())?;
    }
    w.flush().map_err(|e| e.to_string())?;
    lrsched::log_debug!(
        "serve: session closed ({}, {} line(s) read)",
        if shutdown { "shutdown event" } else { "EOF" },
        lineno
    );
    Ok(())
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    logging::init_from_env();
    let (cmd, rest) = match argv.split_first() {
        None => {
            println!("{ABOUT}");
            return Ok(());
        }
        Some((c, r)) => (c.as_str(), r.to_vec()),
    };

    match cmd {
        "help" | "--help" | "-h" => {
            match rest.first().map(|s| s.as_str()) {
                Some("simulate") => println!("{}", cli::usage("simulate", "Run the simulator", &specs::simulate())),
                Some("scale") => println!(
                    "{}",
                    cli::usage(
                        "scale",
                        "Drive a large timed trace through the event engine.\n\
                         Examples:\n\
                           lrsched scale --churn    (100k pods with node\n\
                           joins/drains/crashes and a registry outage window)\n\
                           lrsched scale --churn --shards 4   (sharded per-node\n\
                           event lanes; report byte-identical to --shards 1)\n\
                           lrsched scale --p2p   (peer-swarm layer sharing:\n\
                           LAN fetches from peers instead of WAN re-pulls)\n\
                           lrsched scale --cache-policy lru   (recency-based\n\
                           image GC; also popularity|scorer|prefetch)\n\
                           lrsched scale --trace tests/fixtures/alibaba_mini.csv \\\n\
                             --trace-format alibaba --trace-speedup 10\n\
                         See docs/SCALE.md for the full flag reference.",
                        &specs::scale()
                    )
                ),
                Some("serve") => println!(
                    "{}",
                    cli::usage(
                        "serve",
                        "Online decision service: NDJSON pod/node lifecycle events in,\n\
                         one NDJSON binding decision per pod out (chosen node,\n\
                         per-plugin score breakdown, WAN/P2P pull bytes, decision\n\
                         latency in µs).\n\
                         Examples:\n\
                           lrsched serve < events.ndjson   (stdin session)\n\
                           lrsched serve --listen 127.0.0.1:7473   (HTTP; POST\n\
                           NDJSON to /v1/events, GET /healthz)\n\
                           lrsched serve --shadow tests/fixtures/alibaba_mini.csv\n\
                           (differential: serve decisions must be byte-identical\n\
                           to the batch `scale --trace` replay)\n\
                         See docs/SERVE.md for the full protocol reference.",
                        &specs::serve()
                    )
                ),
                Some("gen-trace") => println!(
                    "{}",
                    cli::usage(
                        "gen-trace",
                        "Write a synthetic Alibaba-dialect trace CSV (or .csv.gz).",
                        &specs::gen_trace()
                    )
                ),
                Some("lint") => println!(
                    "{}",
                    cli::usage(
                        "lint",
                        "Check the crate source against the determinism contract.\n\
                         R1 hash-order escape, R2 ambient nondeterminism, R3 unsafe\n\
                         hygiene, R4 pool-closure accumulation; suppressions use\n\
                         `// det: sorted(<key>)` / `// det: allow(R<n>): <reason>`\n\
                         (see docs/ARCHITECTURE.md, \"Determinism contract\").",
                        &specs::lint()
                    )
                ),
                Some(c @ ("fig3" | "fig4" | "fig5" | "table1")) => {
                    println!("{}", cli::usage(c, "Regenerate a paper experiment", &specs::common()))
                }
                _ => println!("{ABOUT}"),
            }
            Ok(())
        }
        "scale" => run_scale(&rest),
        "serve" => run_serve(&rest),
        "gen-trace" => run_gen_trace(&rest),
        "lint" => run_lint(&rest),
        "simulate" => {
            let args = cli::parse(&rest, &specs::simulate())?;
            apply_log_level(&args)?;
            let seed = args.u64_or("seed", 42)?;
            let pods = args.usize_or("pods", 20)?;
            let nodes = args.usize_or("nodes", 4)?;
            let bw = args.f64_or("bandwidth", 10.0)?;
            let arrival = args.f64_or("arrival", 0.0)?;
            let scheduler = match args.str_or("scheduler", "lr") {
                "default" => SchedulerChoice::Default,
                "layer" => SchedulerChoice::Layer,
                "lr" => SchedulerChoice::LR,
                "rl" => SchedulerChoice::Rl,
                other => return Err(format!("unknown scheduler {other:?}")),
            };
            let mut cfg = SimConfig::default();
            cfg.scheduler = scheduler;
            cfg.bandwidth_mbps = Some(bw);
            cfg.inter_arrival_secs = if arrival > 0.0 { Some(arrival) } else { None };
            cfg.gc_enabled = args.flag("gc");
            let p2p = args.f64_or("p2p-lan", 0.0)?;
            if p2p > 0.0 {
                cfg.p2p_lan_mbps = Some(p2p);
            }

            let registry = Registry::with_corpus();
            let trace =
                WorkloadGen::new(&registry, WorkloadConfig { seed, ..Default::default() }).trace(pods);
            let mut sim = Simulation::new(common::paper_nodes(nodes), registry, cfg);
            if args.str_or("backend", "native") == "xla" {
                let scorer = XlaScorer::load_default().map_err(|e| format!("{e:#}"))?;
                println!("xla backend: variants {:?}", scorer.variant_names());
                sim = sim.with_backend(Box::new(scorer));
            }
            let report = sim.run_trace(trace);
            println!(
                "scheduler={} pods={} deployed={} unschedulable={} failed_pulls={}",
                report.scheduler,
                pods,
                report.deployed(),
                report.unschedulable,
                report.failed_pulls
            );
            println!(
                "total download: {:.1} MB in {:.1} s (virtual); final STD {:.3}; w1/w2 = {}/{}",
                report.total_download().as_mb(),
                report.total_download_secs(),
                report.final_std(),
                report.omega1_used,
                report.omega2_used
            );
            for r in &report.records {
                lrsched::log_debug!(
                    "pod {:>3} {:<24} -> {:<8} dl {:>8.1} MB {:>7.1}s std {:.3}",
                    r.pod.0,
                    r.image,
                    r.node,
                    r.download.as_mb(),
                    r.download_secs,
                    r.std_after
                );
            }
            Ok(())
        }
        "fig3" => {
            let args = cli::parse(&rest, &specs::common())?;
            apply_log_level(&args)?;
            let f = fig3::run(args.u64_or("seed", 42)?, args.usize_or("pods", 20)?);
            print!("{}", f.print());
            Ok(())
        }
        "fig4" => {
            let args = cli::parse(&rest, &specs::common())?;
            apply_log_level(&args)?;
            let f = fig4::run(
                args.u64_or("seed", 42)?,
                args.usize_or("pods", 20)?,
                args.usize_or("nodes", 4)?,
            );
            print!("{}", f.print());
            Ok(())
        }
        "fig5" => {
            let args = cli::parse(&rest, &specs::common())?;
            apply_log_level(&args)?;
            let f = fig5::run(
                args.u64_or("seed", 42)?,
                args.usize_or("pods", 20)?,
                args.usize_or("nodes", 4)?,
            );
            print!("{}", f.print());
            Ok(())
        }
        "table1" => {
            let args = cli::parse(&rest, &specs::common())?;
            apply_log_level(&args)?;
            let t = table1::run(
                args.u64_or("seed", 42)?,
                args.usize_or("pods", 20)?,
                args.usize_or("nodes", 4)?,
            );
            print!("{}", t.print());
            Ok(())
        }
        "export" => {
            let mut spec = specs::common();
            spec.push(OptSpec {
                name: "out",
                help: "output directory",
                default: Some("results"),
            });
            let args = cli::parse(&rest, &spec)?;
            apply_log_level(&args)?;
            let seed = args.u64_or("seed", 42)?;
            let pods = args.usize_or("pods", 20)?;
            let nodes = args.usize_or("nodes", 4)?;
            let dir = std::path::PathBuf::from(args.str_or("out", "results"));
            std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
            let wr = |name: &str, text: String| -> Result<(), String> {
                let p = dir.join(name);
                std::fs::write(&p, text).map_err(|e| e.to_string())?;
                println!("wrote {}", p.display());
                Ok(())
            };
            use lrsched::exp::export;
            wr("fig3.json", export::fig3_to_json(&fig3::run(seed, pods)).to_string_pretty())?;
            wr("fig4.json", export::fig4_to_json(&fig4::run(seed, pods, nodes)).to_string_pretty())?;
            wr("fig5.json", export::fig5_to_json(&fig5::run(seed, pods, nodes)).to_string_pretty())?;
            wr("table1.csv", export::table1_to_csv(&table1::run(seed, pods, nodes)))?;
            Ok(())
        }
        "registry" => {
            let reg = Registry::with_corpus();
            println!("{} images:", reg.image_count());
            for m in reg.all_manifests() {
                println!(
                    "  {:<28} {:>9.1} MB  {} layers",
                    format!("{}:{}", m.name, m.tag),
                    m.total_size.as_mb(),
                    m.layers.len()
                );
            }
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}; try `lrsched help`")),
    }
}

fn apply_log_level(args: &cli::Args) -> Result<(), String> {
    let lvl = args.str_or("log-level", "info");
    logging::set_level(logging::parse_level(lvl).ok_or_else(|| format!("bad log level {lvl:?}"))?);
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}
