//! Canned clusters, caches, and pods shared by integration and property
//! tests.

use crate::cluster::{ClusterState, Node, NodeId, Resources};
use crate::registry::{MetadataCache, Registry, Watcher};
use crate::util::rng::Pcg;
use crate::util::units::{Bandwidth, Bytes};

/// A uniform n-node cluster (4 cores / 4 GB / 30 GB / 10 MB/s each).
pub fn uniform_cluster(n: u32) -> ClusterState {
    let mut s = ClusterState::new();
    for i in 0..n {
        s.add_node(Node::new(
            NodeId(i),
            &format!("node{i}"),
            Resources::cores_gb(4.0, 4.0),
            Bytes::from_gb(30.0),
            Bandwidth::from_mbps(10.0),
        ));
    }
    s
}

/// A heterogeneous cluster drawn from an RNG: capacities, disks, and
/// bandwidths vary (property tests).
pub fn random_cluster(rng: &mut Pcg, n: u32) -> ClusterState {
    let mut s = ClusterState::new();
    for i in 0..n {
        s.add_node(Node::new(
            NodeId(i),
            &format!("node{i}"),
            Resources::cores_gb(rng.range(2, 9) as f64, rng.range(2, 9) as f64),
            Bytes::from_gb(rng.range(10, 61) as f64),
            Bandwidth::from_mbps(rng.range(2, 51) as f64),
        ));
    }
    s
}

/// Generate a synthetic Alibaba-`batch_task`-dialect CSV: Zipf app
/// popularity over `apps` recurring task names, bursty exponential
/// arrivals, heavy-tailed bounded durations, and occasional
/// `instance_num` expansion — the shape the streaming trace importer
/// must sustain at scale. Deterministic per `(rows, seed)`; shared by
/// `bench_scale`, the `gen-trace` CLI subcommand, and the ingestion
/// tests.
pub fn synthetic_alibaba_csv(rows: usize, seed: u64) -> String {
    let mut rng = Pcg::new(seed, 31);
    let weights: Vec<f64> = (1..=40).map(|r| 1.0 / r as f64).collect();
    let mut csv = String::with_capacity(rows * 48);
    let mut start = 86_400.0;
    for j in 0..rows {
        let app = rng.weighted(&weights);
        start += rng.exponential(0.3);
        let dur = rng.exponential(60.0).min(300.0);
        let instances = 1 + rng.range(0, 2);
        let cpu = 20 + rng.range(0, 100);
        let mem = 0.5 + rng.f64() * 4.0;
        csv.push_str(&format!(
            "task_m{app},{instances},j_{j},A,Terminated,{start:.3},{:.3},{cpu},{mem:.2}\n",
            start + dur
        ));
    }
    csv
}

/// A metadata cache filled from the corpus registry.
pub fn corpus_cache() -> MetadataCache {
    let reg = Registry::with_corpus();
    let mut cache = MetadataCache::new("/tmp/lrsched-fixture-cache.json");
    Watcher::with_default_interval().poll(0.0, &reg, &mut cache);
    cache
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixtures_build() {
        assert_eq!(uniform_cluster(4).node_count(), 4);
        let mut rng = Pcg::seeded(1);
        let c = random_cluster(&mut rng, 6);
        assert_eq!(c.node_count(), 6);
        assert_eq!(corpus_cache().len(), 30);
    }
}
