//! InterPodAffinity — "implements inter-Pod affinity and anti-affinity
//! similar to NodeAffinity" (paper §IV-B).
//!
//! For each of the pod's affinity terms, award the term weight for every
//! matching pod in the node's topology domain (negative for anti-affinity
//! terms), then shift+scale to 0–100 across feasible nodes.

use crate::cluster::Node;
use crate::sched::context::CycleContext;
use crate::sched::framework::{ScorePlugin, MAX_NODE_SCORE};

/// InterPodAffinity: attract to / repel from nodes running pods matched
/// by (anti-)affinity terms, within their topology domains.
pub struct InterPodAffinity;

impl ScorePlugin for InterPodAffinity {
    fn name(&self) -> &'static str {
        "InterPodAffinity"
    }

    fn score(&self, ctx: &CycleContext, node: &Node) -> f64 {
        let mut total = 0.0;
        for term in &ctx.pod.pod_affinity {
            let domain = node.labels.get(&term.topology_key);
            for other in ctx.state.nodes() {
                let same_domain = match (&domain, other.labels.get(&term.topology_key)) {
                    // hostname topology: same node only
                    (None, _) | (_, None) => other.id == node.id,
                    (Some(d), Some(od)) => *d == od,
                };
                if !same_domain {
                    continue;
                }
                let matches = ctx
                    .state
                    .pods_on(other.id)
                    .filter(|p| p.labels.get(&term.label_key) == Some(&term.label_value))
                    .count() as f64;
                total += matches * term.weight as f64 * if term.anti { -1.0 } else { 1.0 };
            }
        }
        total
    }

    /// Upstream shifts by the min then scales by the max so anti-affinity
    /// (negative raw) still lands in [0, 100].
    fn normalize(&self, _ctx: &CycleContext, scores: &mut [f64]) {
        let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        if (max - min).abs() < f64::EPSILON {
            for s in scores.iter_mut() {
                *s = MAX_NODE_SCORE;
            }
        } else {
            for s in scores.iter_mut() {
                *s = (*s - min) / (max - min) * MAX_NODE_SCORE;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::pod::PodAffinityTerm;
    use crate::cluster::{ClusterState, Node, NodeId, PodBuilder, Resources};
    use crate::registry::LayerSet;
    use crate::util::units::{Bandwidth, Bytes};

    fn setup() -> (ClusterState, PodBuilder) {
        let mut s = ClusterState::new();
        for (i, zone) in ["a", "b"].iter().enumerate() {
            s.add_node(
                Node::new(
                    NodeId(i as u32),
                    &format!("n{i}"),
                    Resources::cores_gb(4.0, 4.0),
                    Bytes::from_gb(20.0),
                    Bandwidth::from_mbps(10.0),
                )
                .with_label("zone", zone),
            );
        }
        (s, PodBuilder::new())
    }

    fn term(anti: bool) -> PodAffinityTerm {
        PodAffinityTerm {
            label_key: "app".into(),
            label_value: "db".into(),
            topology_key: "zone".into(),
            weight: 10,
            anti,
        }
    }

    #[test]
    fn affinity_attracts_to_cohosted_domain() {
        let (mut state, mut b) = setup();
        let db = b.build("mysql:8.2", Resources::ZERO).with_label("app", "db");
        let pid = state.submit_pod(db);
        state.bind(pid, NodeId(0)).unwrap();

        let mut pod = b.build("wordpress:6.4", Resources::ZERO);
        pod.pod_affinity.push(term(false));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let mut scores = vec![
            InterPodAffinity.score(&ctx, state.node(NodeId(0))),
            InterPodAffinity.score(&ctx, state.node(NodeId(1))),
        ];
        assert_eq!(scores, vec![10.0, 0.0]);
        InterPodAffinity.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![100.0, 0.0]);
    }

    #[test]
    fn anti_affinity_repels() {
        let (mut state, mut b) = setup();
        let db = b.build("mysql:8.2", Resources::ZERO).with_label("app", "db");
        let pid = state.submit_pod(db);
        state.bind(pid, NodeId(0)).unwrap();

        let mut pod = b.build("mysql:8.2", Resources::ZERO);
        pod.pod_affinity.push(term(true));
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let mut scores = vec![
            InterPodAffinity.score(&ctx, state.node(NodeId(0))),
            InterPodAffinity.score(&ctx, state.node(NodeId(1))),
        ];
        assert_eq!(scores, vec![-10.0, 0.0]);
        InterPodAffinity.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![0.0, 100.0]);
    }

    #[test]
    fn no_terms_is_neutral() {
        let (state, mut b) = setup();
        let pod = b.build("redis:7.2", Resources::ZERO);
        let ctx = CycleContext::new(&state, &pod, None, LayerSet::new(), Bytes::ZERO);
        let mut scores = vec![
            InterPodAffinity.score(&ctx, state.node(NodeId(0))),
            InterPodAffinity.score(&ctx, state.node(NodeId(1))),
        ];
        InterPodAffinity.normalize(&ctx, &mut scores);
        assert_eq!(scores, vec![100.0, 100.0]);
    }
}
