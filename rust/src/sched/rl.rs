//! Learning-based scheduler — the paper's §VII future work: "we will
//! design scheduling algorithms using reinforcement learning and other
//! long-term optimization strategies."
//!
//! A contextual ε-greedy bandit with a linear value model: each candidate
//! node is described by a feature vector (layer-sharing score, CPU and
//! memory utilisation, balance STD, normalized S_K8s, feasible-disk
//! headroom); the agent predicts the placement's long-term value, picks
//! argmax with ε-exploration, and updates online from the realized reward
//!   r = −(download MB)/scale − λ·STD_after,
//! i.e. exactly the paper's two objectives (download cost, load balance)
//! folded into one scalar. SGD on squared error keeps it dependency-free
//! and deterministic.

use super::context::CycleContext;
use super::framework::{Framework, NodeScore, Unschedulable};
use super::layer_score;
use crate::cluster::NodeId;
use crate::util::rng::Pcg;

/// Feature count for the linear model (+1 bias).
pub const N_FEATURES: usize = 7;

/// Hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct RlParams {
    /// Initial exploration rate.
    pub epsilon: f64,
    /// ε decay per decision (exploration annealing).
    pub epsilon_decay: f64,
    /// SGD step size for the online update.
    pub learning_rate: f64,
    /// Weight of the balance term in the reward.
    pub lambda_std: f64,
    /// Download normalization scale (MB) so rewards are O(1).
    pub download_scale_mb: f64,
}

impl Default for RlParams {
    fn default() -> RlParams {
        RlParams {
            epsilon: 0.3,
            epsilon_decay: 0.98,
            learning_rate: 0.05,
            lambda_std: 2.0,
            download_scale_mb: 500.0,
        }
    }
}

/// The bandit scheduler. Shares the framework's filter stage with
/// LRScheduler, so hard constraints (Eqs. 6–8) always hold.
pub struct RlScheduler {
    framework: Framework,
    /// Hyper-parameters.
    pub params: RlParams,
    weights: [f64; N_FEATURES + 1],
    epsilon: f64,
    rng: Pcg,
    /// Features of the last decision, kept for the online update.
    last_features: Option<[f64; N_FEATURES + 1]>,
    /// Total decisions taken.
    pub decisions: u64,
    /// Decisions that explored (random pick) instead of exploiting.
    pub explorations: u64,
}

impl RlScheduler {
    /// A fresh agent with zero weights and a seeded exploration RNG.
    pub fn new(framework: Framework, params: RlParams, seed: u64) -> RlScheduler {
        RlScheduler {
            framework,
            params,
            weights: [0.0; N_FEATURES + 1],
            epsilon: params.epsilon,
            rng: Pcg::new(seed, 17),
            last_features: None,
            decisions: 0,
            explorations: 0,
        }
    }

    fn features(&self, ctx: &CycleContext, ns: &NodeScore) -> [f64; N_FEATURES + 1] {
        let node = ctx.state.node(ns.node);
        let local = layer_score::local_bytes(ctx, node);
        let s_layer = layer_score::layer_sharing_score(local, ctx.required_bytes) / 100.0;
        let (cpu, mem) = node.utilisation();
        let std = (cpu - mem).abs() / 2.0;
        let disk_headroom = if node.disk.0 == 0 {
            0.0
        } else {
            node.disk_free().0 as f64 / node.disk.0 as f64
        };
        // S_K8s normalized by the 8-plugin × weight≈12 ceiling.
        let k8s = ns.total / 1200.0;
        [
            s_layer,
            cpu,
            mem,
            std,
            k8s,
            disk_headroom,
            s_layer * (1.0 - cpu), // interaction: sharing on an idle node
            1.0,                   // bias
        ]
    }

    fn predict(&self, f: &[f64; N_FEATURES + 1]) -> f64 {
        self.weights.iter().zip(f).map(|(w, x)| w * x).sum()
    }

    /// One scheduling cycle: filter, featurize, ε-greedy argmax.
    pub fn schedule(&mut self, ctx: &CycleContext) -> Result<NodeId, Unschedulable> {
        let feasible = self.framework.feasible(ctx)?;
        let k8s_scores = self.framework.score(ctx, &feasible);
        self.decisions += 1;
        let explore = self.rng.chance(self.epsilon);
        self.epsilon *= self.params.epsilon_decay;
        let pick = if explore {
            self.explorations += 1;
            self.rng.range(0, k8s_scores.len())
        } else {
            let mut best = 0;
            let mut best_v = f64::NEG_INFINITY;
            for (i, ns) in k8s_scores.iter().enumerate() {
                let v = self.predict(&self.features(ctx, ns));
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            best
        };
        self.last_features = Some(self.features(ctx, &k8s_scores[pick]));
        Ok(k8s_scores[pick].node)
    }

    /// Online update with the realized reward of the last decision.
    pub fn learn(&mut self, download_mb: f64, std_after: f64) {
        let f = match self.last_features.take() {
            Some(f) => f,
            None => return,
        };
        let reward =
            -download_mb / self.params.download_scale_mb - self.params.lambda_std * std_after;
        let err = reward - self.predict(&f);
        for (w, x) in self.weights.iter_mut().zip(&f) {
            *w += self.params.learning_rate * err * x;
        }
    }

    /// The learned linear-model weights (for tests/inspection).
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{NodeId, PodBuilder, Resources};
    use crate::registry::hub;
    use crate::sched::profiles::default_framework;
    use crate::testing::fixtures;

    #[test]
    fn learns_to_prefer_layer_sharing() {
        // Two nodes: node 1 always has the requested image cached, node 0
        // never does. After training, exploitation must pick node 1.
        let mut state = fixtures::uniform_cluster(2);
        let cache = fixtures::corpus_cache();
        let wp = hub::corpus().into_iter().find(|m| m.name == "wordpress" && m.tag == "6.4").unwrap();
        let (_, layers) = state.intern_image(&wp);
        state.install_image(NodeId(1), &wp.image_ref(), &layers).unwrap();

        let mut rl = RlScheduler::new(default_framework(), RlParams::default(), 7);
        let mut b = PodBuilder::new();
        for _ in 0..120 {
            let pod = b.build("wordpress:6.4", Resources::ZERO);
            let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
            let node = {
                let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
                rl.schedule(&ctx).unwrap()
            };
            let download_mb = if node == NodeId(1) { 0.0 } else { wp.total_size.as_mb() };
            rl.learn(download_mb, 0.0);
        }
        // Exploitation phase: force ε to 0 and check the greedy pick.
        rl.epsilon = 0.0;
        let pod = b.build("wordpress:6.4", Resources::ZERO);
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        assert_eq!(rl.schedule(&ctx).unwrap(), NodeId(1));
        assert!(rl.explorations > 0, "ε-greedy must have explored");
        // The layer-sharing feature carries positive weight after training.
        assert!(rl.weights()[0] > 0.0, "weights: {:?}", rl.weights());
    }

    #[test]
    fn respects_filters() {
        let mut state = fixtures::uniform_cluster(2);
        let cache = fixtures::corpus_cache();
        // Node 0 full: only node 1 is feasible; RL must always pick it.
        let mut b = PodBuilder::new();
        let filler = b.build("busybox:1.36", Resources::cores_gb(4.0, 4.0));
        let fid = state.submit_pod(filler);
        state.bind(fid, NodeId(0)).unwrap();

        let mut rl = RlScheduler::new(default_framework(), RlParams::default(), 3);
        for _ in 0..20 {
            let pod = b.build("redis:7.2", Resources::cores_gb(0.1, 0.1));
            let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
            let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
            assert_eq!(rl.schedule(&ctx).unwrap(), NodeId(1));
        }
    }

    #[test]
    fn unschedulable_propagates() {
        let mut state = fixtures::uniform_cluster(1);
        let cache = fixtures::corpus_cache();
        let mut b = PodBuilder::new();
        let pod = b.build("redis:7.2", Resources::cores_gb(64.0, 64.0));
        let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
        let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
        let mut rl = RlScheduler::new(default_framework(), RlParams::default(), 1);
        assert!(rl.schedule(&ctx).is_err());
        // learn() without a pending decision is a no-op.
        rl.learn(0.0, 0.0);
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let run = || {
            let mut state = fixtures::uniform_cluster(3);
            let cache = fixtures::corpus_cache();
            let mut rl = RlScheduler::new(default_framework(), RlParams::default(), 99);
            let mut b = PodBuilder::new();
            let mut picks = Vec::new();
            for i in 0..30 {
                let img = if i % 2 == 0 { "redis:7.2" } else { "nginx:1.25" };
                let pod = b.build(img, Resources::cores_gb(0.05, 0.05));
                let (meta, req, bytes) = CycleContext::prepare(&mut state, &cache, &pod);
                let node = {
                    let ctx = CycleContext::new(&state, &pod, meta, req, bytes);
                    rl.schedule(&ctx).unwrap()
                };
                rl.learn(10.0, 0.1);
                picks.push(node);
            }
            picks
        };
        assert_eq!(run(), run());
    }
}
