//! Mini property-testing harness (proptest is not in the vendored set).
//!
//! A property runs N seeded cases; on failure it reports the failing seed
//! so the case replays deterministically (`PropError` carries the seed) and
//! performs a simple shrink pass over the case's "size" knob when the
//! generator supports it.

use crate::util::rng::Pcg;

/// Configuration for one property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    /// Cases to run (`LRSCHED_PROP_CASES` overrides).
    pub cases: usize,
    /// Base seed (`PROPTEST_SEED` overrides).
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> PropConfig {
        // LRSCHED_PROP_CASES overrides for soak runs; PROPTEST_SEED
        // re-seeds the whole suite (the CI matrix runs several seeds so
        // seed-specific passes can't hide invariant violations).
        let cases = std::env::var("LRSCHED_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0x5eed);
        PropConfig { cases, seed }
    }
}

/// A failing case.
#[derive(Debug, Clone)]
pub struct PropError {
    /// Which case failed.
    pub case: usize,
    /// Seed that replays the failure.
    pub seed: u64,
    /// The property's failure message.
    pub message: String,
}

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (replay seed {:#x}): {}",
            self.case, self.seed, self.message
        )
    }
}

/// Run `property(rng, case_index)` for `cfg.cases` cases; each case gets an
/// independent RNG stream derived from the base seed, so failures replay.
pub fn check<F>(cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Pcg, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Pcg::new(case_seed, case as u64);
        if let Err(message) = property(&mut rng, case) {
            panic!("{}", PropError { case, seed: case_seed, message });
        }
    }
}

/// Assert inside a property body, returning `Err` with the formatted
/// message instead of panicking (so the harness can report the seed).
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// `prop_assert!` specialization for equality with Debug output.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(PropConfig { cases: 32, seed: 1 }, |rng, _| {
            let x = rng.range(0, 100);
            prop_assert!(x < 100, "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check(PropConfig { cases: 32, seed: 1 }, |rng, _| {
            let x = rng.range(0, 100);
            prop_assert!(x < 50, "x={x} escaped");
            Ok(())
        });
    }

    #[test]
    fn cases_get_distinct_streams() {
        let mut firsts = Vec::new();
        check(PropConfig { cases: 8, seed: 2 }, |rng, _| {
            firsts.push(rng.next_u64());
            Ok(())
        });
        firsts.sort();
        firsts.dedup();
        assert_eq!(firsts.len(), 8);
    }
}
