//! Synthetic Docker Hub corpus.
//!
//! The paper's workload pulls real images (WordPress, Ghost, GCC, Redis,
//! Tomcat, MySQL, …) from a private registry. We have no network, so this
//! module encodes a 30-image corpus whose *layer-sharing topology* and size
//! distribution mirror the real images: official images share OS base
//! layers (debian/alpine/ubuntu), language stacks (php/node/openjdk/python)
//! share runtime layers, and each image adds unique app layers.
//! Sizes are modeled on Docker Hub published compressed sizes (±, rounded).
//!
//! The sharing topology is what drives every result in the paper — two
//! images that share a 49 MB debian base produce exactly the download-cost
//! asymmetry Eq. (1) rewards — so this is the substitution that preserves
//! behaviour (see DESIGN.md §1).

use super::image::ImageMetadata;
use super::layer::LayerMetadata;
use crate::util::units::Bytes;

/// A corpus entry: image name, tag, and its layer stack. Layers with equal
/// names are the *same* content-addressed layer across images.
struct Entry {
    name: &'static str,
    tag: &'static str,
    /// (shared-layer-name, size in MB)
    layers: &'static [(&'static str, f64)],
}

// --- shared layer building blocks -----------------------------------------
// OS bases
const DEBIAN12: (&str, f64) = ("os.debian12", 49.0);
const DEBIAN11: (&str, f64) = ("os.debian11", 52.0);
const ALPINE: (&str, f64) = ("os.alpine319", 3.4);
const UBUNTU: (&str, f64) = ("os.ubuntu2204", 29.0);
// common dependency bundles (buildpack-deps style)
const CA_CERTS: (&str, f64) = ("dep.ca-certs", 3.0);
const CURL_DEPS: (&str, f64) = ("dep.curl", 48.0);
const SCM_DEPS: (&str, f64) = ("dep.scm", 57.0);
const BUILD_DEPS: (&str, f64) = ("dep.buildpack-full", 310.0);
// language runtimes
const PHP_RUNTIME: (&str, f64) = ("rt.php82", 31.0);
const PHP_EXTS: (&str, f64) = ("rt.php82-exts", 52.0);
const APACHE: (&str, f64) = ("rt.apache24", 21.0);
const NODE18: (&str, f64) = ("rt.node18", 48.0);
const NODE_MODULES: (&str, f64) = ("rt.node18-yarn", 12.0);
const JRE17: (&str, f64) = ("rt.jre17", 92.0);
const JDK17: (&str, f64) = ("rt.jdk17", 188.0);
const PY311: (&str, f64) = ("rt.python311", 19.0);
const PY_PIP: (&str, f64) = ("rt.python-pip", 11.0);
const GOLANG: (&str, f64) = ("rt.go121", 68.0);

/// The corpus. 30 images across the families the paper names plus the
/// surrounding official-image ecosystem.
const CORPUS: &[Entry] = &[
    // --- images the paper names explicitly -------------------------------
    Entry {
        name: "wordpress",
        tag: "6.4",
        layers: &[DEBIAN12, CA_CERTS, APACHE, PHP_RUNTIME, PHP_EXTS, ("app.wordpress", 87.0)],
    },
    Entry {
        name: "ghost",
        tag: "5",
        layers: &[DEBIAN12, CA_CERTS, NODE18, NODE_MODULES, ("app.ghost", 171.0)],
    },
    Entry {
        name: "gcc",
        tag: "13",
        layers: &[DEBIAN12, CURL_DEPS, SCM_DEPS, BUILD_DEPS, ("app.gcc13", 360.0)],
    },
    Entry {
        name: "redis",
        tag: "7.2",
        layers: &[DEBIAN12, CA_CERTS, ("app.redis72", 12.0), ("cfg.redis", 0.4)],
    },
    Entry {
        name: "tomcat",
        tag: "10",
        layers: &[UBUNTU, CA_CERTS, JRE17, ("app.tomcat10", 24.0)],
    },
    Entry {
        name: "mysql",
        tag: "8.2",
        layers: &[("os.oraclelinux9", 38.0), ("app.mysql-server", 142.0), ("cfg.mysql", 2.0)],
    },
    // --- same-family variants (high sharing with the above) --------------
    Entry {
        name: "redis",
        tag: "7.2-alpine",
        layers: &[ALPINE, ("app.redis72-alpine", 10.5)],
    },
    Entry {
        name: "wordpress",
        tag: "6.4-php8.2",
        layers: &[DEBIAN12, CA_CERTS, APACHE, PHP_RUNTIME, PHP_EXTS, ("app.wordpress-fpm", 84.0)],
    },
    Entry {
        name: "tomcat",
        tag: "10-jdk17",
        layers: &[UBUNTU, CA_CERTS, JDK17, ("app.tomcat10", 24.0)],
    },
    Entry {
        name: "mariadb",
        tag: "11",
        layers: &[UBUNTU, CA_CERTS, ("app.mariadb11", 106.0)],
    },
    // --- broader official-image ecosystem --------------------------------
    Entry {
        name: "nginx",
        tag: "1.25",
        layers: &[DEBIAN12, CA_CERTS, ("app.nginx125", 19.0), ("cfg.nginx", 0.6)],
    },
    Entry {
        name: "httpd",
        tag: "2.4",
        layers: &[DEBIAN12, CA_CERTS, APACHE, ("app.httpd24", 9.0)],
    },
    Entry {
        name: "postgres",
        tag: "16",
        layers: &[DEBIAN12, CA_CERTS, ("app.postgres16", 96.0), ("cfg.postgres", 1.5)],
    },
    Entry {
        name: "python",
        tag: "3.11",
        layers: &[DEBIAN12, CURL_DEPS, PY311, PY_PIP],
    },
    Entry {
        name: "python",
        tag: "3.11-full",
        layers: &[DEBIAN12, CURL_DEPS, SCM_DEPS, BUILD_DEPS, PY311, PY_PIP],
    },
    Entry {
        name: "node",
        tag: "18",
        layers: &[DEBIAN12, CURL_DEPS, SCM_DEPS, BUILD_DEPS, NODE18, NODE_MODULES],
    },
    Entry {
        name: "node",
        tag: "18-slim",
        layers: &[DEBIAN12, CA_CERTS, NODE18],
    },
    Entry {
        name: "golang",
        tag: "1.21",
        layers: &[DEBIAN12, CURL_DEPS, SCM_DEPS, BUILD_DEPS, GOLANG],
    },
    Entry {
        name: "php",
        tag: "8.2-apache",
        layers: &[DEBIAN12, CA_CERTS, APACHE, PHP_RUNTIME],
    },
    Entry {
        name: "php",
        tag: "8.2-fpm",
        layers: &[DEBIAN12, CA_CERTS, PHP_RUNTIME, ("rt.php82-fpm", 6.0)],
    },
    Entry {
        name: "memcached",
        tag: "1.6",
        layers: &[DEBIAN11, CA_CERTS, ("app.memcached16", 4.2)],
    },
    Entry {
        name: "rabbitmq",
        tag: "3.12",
        layers: &[UBUNTU, CA_CERTS, ("rt.erlang26", 28.0), ("app.rabbitmq312", 32.0)],
    },
    Entry {
        name: "mongo",
        tag: "7",
        layers: &[UBUNTU, CA_CERTS, ("app.mongod7", 197.0), ("cfg.mongo", 1.0)],
    },
    Entry {
        name: "elasticsearch",
        tag: "8.11",
        layers: &[UBUNTU, CA_CERTS, JDK17, ("app.elastic811", 340.0)],
    },
    Entry {
        name: "jenkins",
        tag: "lts",
        layers: &[DEBIAN11, CA_CERTS, JDK17, ("app.jenkins-lts", 95.0)],
    },
    Entry {
        name: "registry",
        tag: "2",
        layers: &[ALPINE, ("app.registry2", 7.8)],
    },
    Entry {
        name: "busybox",
        tag: "1.36",
        layers: &[("os.busybox136", 2.2)],
    },
    Entry {
        name: "alpine",
        tag: "3.19",
        layers: &[ALPINE],
    },
    Entry {
        name: "haproxy",
        tag: "2.8",
        layers: &[DEBIAN12, CA_CERTS, ("app.haproxy28", 10.0)],
    },
    Entry {
        name: "grafana",
        tag: "10",
        layers: &[ALPINE, ("dep.alpine-libs", 6.0), ("app.grafana10", 111.0)],
    },
];

/// Build the corpus as registry metadata. Layer digests are derived from
/// the shared layer names, so equal names ⇒ equal digests ⇒ sharing.
pub fn corpus() -> Vec<ImageMetadata> {
    CORPUS
        .iter()
        .map(|e| {
            let layers: Vec<LayerMetadata> = e
                .layers
                .iter()
                .map(|(lname, mb)| LayerMetadata {
                    digest: digest_for(lname),
                    size: Bytes::from_mb(*mb),
                })
                .collect();
            ImageMetadata::new(&digest_for(&format!("manifest.{}:{}", e.name, e.tag)), e.name, e.tag, layers)
        })
        .collect()
}

/// Deterministic pseudo-digest from a layer name (FNV-1a, hex-expanded).
/// Real registries use sha256 of content; the scheduler only needs identity.
pub fn digest_for(name: &str) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    // Second pass with a different seed to fill 128 bits.
    let mut h2: u64 = 0x9e3779b97f4a7c15;
    for b in name.bytes().rev() {
        h2 ^= b as u64;
        h2 = h2.wrapping_mul(0x100000001b3);
    }
    format!("sha256:{h:016x}{h2:016x}")
}

/// Names of the six images the paper's §VI-A lists explicitly.
pub fn paper_images() -> Vec<&'static str> {
    vec!["wordpress", "ghost", "gcc", "redis", "tomcat", "mysql"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::{HashMap, HashSet};

    #[test]
    fn corpus_has_30_images() {
        assert_eq!(corpus().len(), 30);
    }

    #[test]
    fn paper_images_present() {
        let c = corpus();
        for name in paper_images() {
            assert!(c.iter().any(|m| m.name == name), "missing {name}");
        }
    }

    #[test]
    fn digests_are_stable_and_distinct() {
        assert_eq!(digest_for("os.debian12"), digest_for("os.debian12"));
        let mut seen = HashSet::new();
        for m in corpus() {
            for l in &m.layers {
                seen.insert(l.digest.clone());
            }
        }
        // 30 images but far fewer distinct layers than total references.
        let total_refs: usize = corpus().iter().map(|m| m.layers.len()).sum();
        assert!(seen.len() < total_refs, "no sharing at all?");
        assert!(seen.len() > 30, "suspiciously few distinct layers");
    }

    #[test]
    fn shared_layers_have_identical_size_everywhere() {
        let mut sizes: HashMap<String, Bytes> = HashMap::new();
        for m in corpus() {
            for l in &m.layers {
                let prev = sizes.insert(l.digest.clone(), l.size);
                if let Some(p) = prev {
                    assert_eq!(p, l.size, "layer {} size mismatch", l.digest);
                }
            }
        }
    }

    #[test]
    fn debian_base_is_widely_shared() {
        let base = digest_for("os.debian12");
        let sharers = corpus()
            .iter()
            .filter(|m| m.layers.iter().any(|l| l.digest == base))
            .count();
        assert!(sharers >= 10, "debian base shared by only {sharers}");
    }

    #[test]
    fn image_sizes_are_realistic() {
        let c = corpus();
        let gcc = c.iter().find(|m| m.name == "gcc").unwrap();
        assert!(gcc.total_size > Bytes::from_mb(700.0), "gcc should be huge");
        let alpine = c.iter().find(|m| m.name == "alpine").unwrap();
        assert!(alpine.total_size < Bytes::from_mb(5.0));
        // No image is zero-sized.
        for m in &c {
            assert!(m.total_size > Bytes::ZERO, "{} empty", m.name);
        }
    }

    #[test]
    fn name_tag_pairs_unique() {
        let mut seen = HashSet::new();
        for m in corpus() {
            assert!(seen.insert(m.image_ref().key()), "duplicate {}", m.image_ref());
        }
    }

    #[test]
    fn redis_variants_share_little() {
        // debian redis vs alpine redis share no layers — different bases.
        let c = corpus();
        let deb: HashSet<_> = c
            .iter()
            .find(|m| m.name == "redis" && m.tag == "7.2")
            .unwrap()
            .layers
            .iter()
            .map(|l| l.digest.clone())
            .collect();
        let alp: HashSet<_> = c
            .iter()
            .find(|m| m.tag == "7.2-alpine")
            .unwrap()
            .layers
            .iter()
            .map(|l| l.digest.clone())
            .collect();
        assert!(deb.is_disjoint(&alp));
    }
}
